//! Paginated sweep reports (`slfac-sweep/1`).
//!
//! A report page is a self-describing JSON document over a prefix of the
//! journal: header fields (sweep, fingerprint, grid, completed), the page
//! of run records, and keyset-pagination cursors. Cursors are
//! `run:<run_id>` strings — pass a page's `next_cursor` back to get the
//! records *after* that run.
//!
//! Stability contract: because records are journaled in dense `run_id`
//! order and every field of a record is deterministic, a **full** page
//! (one holding `page_size` records) is byte-identical no matter how much
//! of the sweep has completed since — its `next_cursor` depends only on
//! the page's own last record and the (fixed) grid size, never on the
//! current completion count. Only the frontier partial page changes as
//! the sweep progresses, by gaining records. Consumers can therefore
//! cache full pages of a sweep that is still executing.

use crate::bench::report;
use crate::json::Json;
use crate::sweep::journal::{JournalHeader, RunRecord};
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Schema family for report pages; full id is `slfac-sweep/1`.
pub const REPORT_FAMILY: &str = "sweep";
/// Current report schema version.
pub const REPORT_VERSION: u32 = 1;

/// The cursor naming a run: page requests resume *after* it.
pub fn cursor_for(run_id: usize) -> String {
    format!("run:{run_id}")
}

/// Parse a `run:<id>` cursor.
pub fn parse_cursor(s: &str) -> Result<usize> {
    s.strip_prefix("run:")
        .and_then(|id| id.parse().ok())
        .with_context(|| format!("bad cursor '{s}' (expected 'run:<id>')"))
}

/// Build one report page over the journaled `records`, starting after
/// `cursor` (from the beginning when `None`). `page_size == 0` means
/// unpaginated: everything from the cursor on. Records must be in dense
/// `run_id` order, as [`Journal::open`](crate::sweep::Journal::open)
/// guarantees.
pub fn page(
    header: &JournalHeader,
    records: &[RunRecord],
    cursor: Option<usize>,
    page_size: usize,
) -> Json {
    let from = cursor.map(|c| c + 1).unwrap_or(0).min(records.len());
    let until = if page_size == 0 {
        records.len()
    } else {
        (from + page_size).min(records.len())
    };
    let slice = &records[from..until];
    // keyset semantics: the next cursor is a function of this page's own
    // records and the fixed grid size — NOT of records.len() — so a full
    // page's bytes never change as the journal grows behind it.
    let next_cursor = match slice.last() {
        Some(last) if last.run_id + 1 < header.grid => Json::Str(cursor_for(last.run_id)),
        _ => Json::Null,
    };
    let mut m = BTreeMap::new();
    m.insert("sweep".to_string(), Json::Str(header.sweep.clone()));
    m.insert("fingerprint".to_string(), Json::Str(header.fingerprint.clone()));
    m.insert("grid".to_string(), Json::Num(header.grid as f64));
    m.insert("completed".to_string(), Json::Num(records.len() as f64));
    m.insert(
        "cursor".to_string(),
        match cursor {
            Some(c) => Json::Str(cursor_for(c)),
            None => Json::Null,
        },
    );
    m.insert("page_size".to_string(), Json::Num(page_size as f64));
    m.insert("next_cursor".to_string(), next_cursor);
    m.insert(
        "runs".to_string(),
        Json::Arr(slice.iter().map(|r| r.to_json()).collect()),
    );
    report::versioned(REPORT_FAMILY, REPORT_VERSION, m)
}

/// Walk the whole journal as a sequence of pages (the last may be
/// partial). With `page_size == 0`, a single unpaginated page. Never
/// emits a trailing empty page.
pub fn pages(header: &JournalHeader, records: &[RunRecord], page_size: usize) -> Vec<Json> {
    if page_size == 0 {
        return vec![page(header, records, None, 0)];
    }
    let mut out = Vec::new();
    let mut from = 0usize;
    loop {
        // records are dense, so the cursor before index `from` is simply
        // the previous record's run_id
        let cursor = if from == 0 {
            None
        } else {
            Some(records[from - 1].run_id)
        };
        out.push(page(header, records, cursor, page_size));
        from += page_size;
        if from >= records.len() {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::journal::RunMetrics;

    fn header(grid: usize) -> JournalHeader {
        JournalHeader {
            sweep: "g".into(),
            fingerprint: "00000000deadbeef".into(),
            grid,
        }
    }

    fn record(run_id: usize) -> RunRecord {
        RunRecord {
            run_id,
            name: format!("g_run{run_id}"),
            axes: BTreeMap::new(),
            config_fp: "0".repeat(16),
            metrics: RunMetrics {
                rounds: 1,
                final_train_loss: 1.0,
                final_test_loss: 1.0,
                final_test_acc: 0.5,
                best_test_acc: 0.5,
                uplink_bytes: 1,
                downlink_bytes: 1,
                total_bytes: 2,
                makespan_s: 1.0,
                queue_wait_s: 0.0,
                dropped_devices: 0,
            },
        }
    }

    fn records(n: usize) -> Vec<RunRecord> {
        (0..n).map(record).collect()
    }

    fn runs_in(p: &Json) -> Vec<usize> {
        p.get("runs")
            .and_then(|r| r.as_arr())
            .unwrap()
            .iter()
            .map(|r| r.get("run_id").and_then(|v| v.as_usize()).unwrap())
            .collect()
    }

    #[test]
    fn cursors_roundtrip_and_reject_garbage() {
        assert_eq!(parse_cursor(&cursor_for(17)).unwrap(), 17);
        for bad in ["", "17", "run:", "run:x", "page:3"] {
            let err = parse_cursor(bad).unwrap_err();
            assert!(format!("{err:#}").contains(bad), "{err:#}");
        }
    }

    #[test]
    fn pages_slice_the_journal_in_order() {
        let h = header(5);
        let rs = records(5);
        let p1 = page(&h, &rs, None, 2);
        assert_eq!(runs_in(&p1), [0, 1]);
        assert_eq!(p1.get("next_cursor").and_then(|c| c.as_str()), Some("run:1"));
        assert_eq!(p1.get("cursor"), Some(&Json::Null));
        let p2 = page(&h, &rs, Some(1), 2);
        assert_eq!(runs_in(&p2), [2, 3]);
        assert_eq!(p2.get("cursor").and_then(|c| c.as_str()), Some("run:1"));
        let p3 = page(&h, &rs, Some(3), 2);
        assert_eq!(runs_in(&p3), [4]);
        // last run of the grid ⇒ chain terminates
        assert_eq!(p3.get("next_cursor"), Some(&Json::Null));
        assert_eq!(p3.get("completed").and_then(|c| c.as_usize()), Some(5));
    }

    #[test]
    fn full_pages_are_stable_as_the_journal_grows() {
        let h = header(6);
        let early = page(&h, &records(2), None, 2);
        let late = page(&h, &records(6), None, 2);
        assert_eq!(
            early.get("runs"),
            late.get("runs"),
            "a full page's records must not change"
        );
        // next_cursor matches too: grid says more runs exist either way
        assert_eq!(early.get("next_cursor"), late.get("next_cursor"));
        // completed is the only field allowed to differ
        assert_ne!(early.get("completed"), late.get("completed"));
    }

    #[test]
    fn frontier_page_past_the_journal_is_empty_not_an_error() {
        let h = header(8);
        let p = page(&h, &records(3), Some(5), 2);
        assert!(runs_in(&p).is_empty());
        assert_eq!(p.get("next_cursor"), Some(&Json::Null));
    }

    #[test]
    fn unpaginated_page_holds_everything() {
        let h = header(4);
        let p = page(&h, &records(3), None, 0);
        assert_eq!(runs_in(&p), [0, 1, 2]);
        // grid not yet complete ⇒ the chain continues from run 2
        assert_eq!(p.get("next_cursor").and_then(|c| c.as_str()), Some("run:2"));
        let schema = p.get("schema").and_then(|s| s.as_str()).unwrap();
        assert_eq!(schema, "slfac-sweep/1");
    }

    #[test]
    fn pages_helper_covers_without_overlap() {
        let h = header(7);
        let rs = records(7);
        let all = pages(&h, &rs, 3);
        assert_eq!(all.len(), 3);
        let ids: Vec<usize> = all.iter().flat_map(runs_in).collect();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        assert_eq!(all[2].get("next_cursor"), Some(&Json::Null));
        // no trailing empty page even when the journal divides evenly
        let even = pages(&header(6), &records(6), 3);
        assert_eq!(even.len(), 2);
        assert!(!runs_in(&even[1]).is_empty());
    }
}
