//! Append-only sweep results journal (`slfac-sweep-journal/1`).
//!
//! One JSON document per line: the header (sweep name, spec fingerprint,
//! grid size) on line 1, then one [`RunRecord`] per completed run, in
//! dense `run_id` order. Resume = read the journal, skip the first
//! `records().len()` runs of the expanded grid.
//!
//! Crash safety: a record only counts once its trailing newline is on
//! disk. An unterminated tail (torn write from a killed process) is
//! ignored on open and truncated away by the first append, so a resumed
//! sweep re-executes the torn run and rewrites the line — determinism
//! makes the rewrite byte-identical to what an uninterrupted sweep would
//! have produced.

use crate::bench::report;
use crate::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Seek, SeekFrom, Write};

/// Schema family for journal lines; full id is `slfac-sweep-journal/1`.
pub const JOURNAL_FAMILY: &str = "sweep-journal";
/// Current journal schema version.
pub const JOURNAL_VERSION: u32 = 1;

/// Journal line 1: identifies which sweep the records belong to.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalHeader {
    /// Sweep name from the spec.
    pub sweep: String,
    /// Hex [`SweepSpec::fingerprint_hex`](crate::sweep::SweepSpec::fingerprint_hex)
    /// of the spec this journal was created for.
    pub fingerprint: String,
    /// Grid size the spec expands to.
    pub grid: usize,
}

impl JournalHeader {
    /// Serialize as the journal's first line.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("sweep".to_string(), Json::Str(self.sweep.clone()));
        m.insert("fingerprint".to_string(), Json::Str(self.fingerprint.clone()));
        m.insert("grid".to_string(), Json::Num(self.grid as f64));
        report::versioned(JOURNAL_FAMILY, JOURNAL_VERSION, m)
    }

    /// Parse a header line, checking the schema id.
    pub fn from_json(json: &Json) -> Result<Self> {
        let obj = json.as_obj().context("journal header must be an object")?;
        let schema = obj
            .get("schema")
            .and_then(|s| s.as_str())
            .context("journal header missing 'schema'")?;
        let want = report::schema_id(JOURNAL_FAMILY, JOURNAL_VERSION);
        if schema != want {
            bail!("journal schema '{schema}' is not '{want}'");
        }
        Ok(JournalHeader {
            sweep: obj
                .get("sweep")
                .and_then(|s| s.as_str())
                .context("journal header missing 'sweep'")?
                .to_string(),
            fingerprint: obj
                .get("fingerprint")
                .and_then(|s| s.as_str())
                .context("journal header missing 'fingerprint'")?
                .to_string(),
            grid: obj
                .get("grid")
                .and_then(|g| g.as_usize())
                .context("journal header missing 'grid'")?,
        })
    }
}

/// The deterministic per-run results pinned by the journal.
#[derive(Debug, Clone, PartialEq)]
pub struct RunMetrics {
    /// Rounds the run executed.
    pub rounds: usize,
    /// Training loss at the final round.
    pub final_train_loss: f64,
    /// Test loss at the final round.
    pub final_test_loss: f64,
    /// Test accuracy at the final round.
    pub final_test_acc: f64,
    /// Best test accuracy over all rounds.
    pub best_test_acc: f64,
    /// Total uplink bytes across rounds.
    pub uplink_bytes: u64,
    /// Total downlink bytes across rounds.
    pub downlink_bytes: u64,
    /// Uplink + downlink.
    pub total_bytes: u64,
    /// Simulated communication makespan, seconds.
    pub makespan_s: f64,
    /// Summed queue-wait across rounds, seconds.
    pub queue_wait_s: f64,
    /// Summed deadline-dropped device count across rounds.
    pub dropped_devices: u64,
}

/// One journal line: a completed run and its metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    /// Dense grid index (must equal the line's position in the journal).
    pub run_id: usize,
    /// Generated run name.
    pub name: String,
    /// Axis key → chosen scalar value.
    pub axes: BTreeMap<String, Json>,
    /// Hex fingerprint of the run's canonical
    /// [`ExperimentConfig::to_json`](crate::config::ExperimentConfig::to_json),
    /// so resume detects a spec whose expansion drifted.
    pub config_fp: String,
    /// The run's results.
    pub metrics: RunMetrics,
}

impl RunRecord {
    /// Serialize as a journal line / report `runs[]` entry. f64 fields use
    /// the shortest-roundtrip formatter, so equal bits ⇒ equal text ⇒
    /// byte-identical journals.
    pub fn to_json(&self) -> Json {
        let m = &self.metrics;
        let mut j = BTreeMap::new();
        j.insert("run_id".to_string(), Json::Num(self.run_id as f64));
        j.insert("name".to_string(), Json::Str(self.name.clone()));
        j.insert("axes".to_string(), Json::Obj(self.axes.clone()));
        j.insert("config_fp".to_string(), Json::Str(self.config_fp.clone()));
        j.insert("rounds".to_string(), Json::Num(m.rounds as f64));
        j.insert("final_train_loss".to_string(), Json::Num(m.final_train_loss));
        j.insert("final_test_loss".to_string(), Json::Num(m.final_test_loss));
        j.insert("final_test_acc".to_string(), Json::Num(m.final_test_acc));
        j.insert("best_test_acc".to_string(), Json::Num(m.best_test_acc));
        j.insert("uplink_bytes".to_string(), Json::Num(m.uplink_bytes as f64));
        j.insert("downlink_bytes".to_string(), Json::Num(m.downlink_bytes as f64));
        j.insert("total_bytes".to_string(), Json::Num(m.total_bytes as f64));
        j.insert("makespan_s".to_string(), Json::Num(m.makespan_s));
        j.insert("queue_wait_s".to_string(), Json::Num(m.queue_wait_s));
        j.insert("dropped_devices".to_string(), Json::Num(m.dropped_devices as f64));
        Json::Obj(j)
    }

    /// Parse a journal line.
    pub fn from_json(json: &Json) -> Result<Self> {
        let obj = json.as_obj().context("journal record must be an object")?;
        let f = |key: &str| -> Result<f64> {
            obj.get(key)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("journal record missing '{key}'"))
        };
        let u = |key: &str| -> Result<u64> { Ok(f(key)? as u64) };
        let axes = match obj.get("axes") {
            Some(Json::Obj(m)) => m.clone(),
            Some(_) => bail!("journal record 'axes' must be an object"),
            None => bail!("journal record missing 'axes'"),
        };
        Ok(RunRecord {
            run_id: obj
                .get("run_id")
                .and_then(|v| v.as_usize())
                .context("journal record missing 'run_id'")?,
            name: obj
                .get("name")
                .and_then(|v| v.as_str())
                .context("journal record missing 'name'")?
                .to_string(),
            axes,
            config_fp: obj
                .get("config_fp")
                .and_then(|v| v.as_str())
                .context("journal record missing 'config_fp'")?
                .to_string(),
            metrics: RunMetrics {
                rounds: f("rounds")? as usize,
                final_train_loss: f("final_train_loss")?,
                final_test_loss: f("final_test_loss")?,
                final_test_acc: f("final_test_acc")?,
                best_test_acc: f("best_test_acc")?,
                uplink_bytes: u("uplink_bytes")?,
                downlink_bytes: u("downlink_bytes")?,
                total_bytes: u("total_bytes")?,
                makespan_s: f("makespan_s")?,
                queue_wait_s: f("queue_wait_s")?,
                dropped_devices: u("dropped_devices")?,
            },
        })
    }
}

/// An open journal file: parsed header + records, plus the byte length of
/// the valid (newline-terminated) prefix so appends can truncate a torn
/// tail first.
#[derive(Debug)]
pub struct Journal {
    path: String,
    header: JournalHeader,
    records: Vec<RunRecord>,
    valid_len: u64,
}

impl Journal {
    /// Create a fresh journal at `path` (parent directories included),
    /// writing the header line. Fails if the file already exists — use
    /// [`Journal::open_or_create`] for resume semantics.
    pub fn create(path: &str, header: JournalHeader) -> Result<Journal> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let line = format!("{}\n", header.to_json().to_string());
        let mut file = std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(path)
            .with_context(|| format!("creating journal {path}"))?;
        file.write_all(line.as_bytes())
            .and_then(|()| file.flush())
            .with_context(|| format!("writing journal header to {path}"))?;
        Ok(Journal {
            path: path.to_string(),
            header,
            records: Vec::new(),
            valid_len: line.len() as u64,
        })
    }

    /// Open an existing journal, validating the header schema and dense
    /// record order. An unterminated final line is treated as a torn
    /// write: it is not parsed, and the next append truncates it.
    pub fn open(path: &str) -> Result<Journal> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading journal {path}"))?;
        // the valid prefix ends at the last newline; anything after it is
        // a torn tail from an interrupted append
        let valid = match text.rfind('\n') {
            Some(pos) => &text[..=pos],
            None => bail!("journal {path} has no complete lines"),
        };
        let mut lines = valid.lines();
        let header_line = lines
            .next()
            .with_context(|| format!("journal {path} is empty"))?;
        let header = Json::parse(header_line)
            .map_err(anyhow::Error::from)
            .and_then(|j| JournalHeader::from_json(&j))
            .with_context(|| format!("journal {path} line 1"))?;
        let mut records = Vec::new();
        for (i, line) in lines.enumerate() {
            let rec = Json::parse(line)
                .map_err(anyhow::Error::from)
                .and_then(|j| RunRecord::from_json(&j))
                .with_context(|| format!("journal {path} line {}", i + 2))?;
            if rec.run_id != records.len() {
                bail!(
                    "journal {path} line {}: run_id {} out of order (expected {})",
                    i + 2,
                    rec.run_id,
                    records.len()
                );
            }
            records.push(rec);
        }
        Ok(Journal {
            path: path.to_string(),
            header,
            records,
            valid_len: valid.len() as u64,
        })
    }

    /// Open `path` if it exists, else create it with `header`.
    pub fn open_or_create(path: &str, header: JournalHeader) -> Result<Journal> {
        if std::path::Path::new(path).exists() {
            Journal::open(path)
        } else {
            Journal::create(path, header)
        }
    }

    /// Append a completed run. `rec.run_id` must be the next dense index.
    /// Truncates any torn tail, then writes the full line + newline and
    /// flushes before returning, so a record is durable once this returns.
    pub fn append(&mut self, rec: RunRecord) -> Result<()> {
        if rec.run_id != self.records.len() {
            bail!(
                "journal {}: appending run_id {} but {} records are journaled",
                self.path,
                rec.run_id,
                self.records.len()
            );
        }
        let line = format!("{}\n", rec.to_json().to_string());
        let mut file = std::fs::OpenOptions::new()
            .write(true)
            .open(&self.path)
            .with_context(|| format!("opening journal {}", self.path))?;
        file.set_len(self.valid_len)
            .and_then(|()| file.seek(SeekFrom::End(0)))
            .and_then(|_| file.write_all(line.as_bytes()))
            .and_then(|()| file.flush())
            .with_context(|| format!("appending to journal {}", self.path))?;
        self.valid_len += line.len() as u64;
        self.records.push(rec);
        Ok(())
    }

    /// The journal's header.
    pub fn header(&self) -> &JournalHeader {
        &self.header
    }

    /// Journaled records, in dense `run_id` order.
    pub fn records(&self) -> &[RunRecord] {
        &self.records
    }

    /// Number of completed (journaled) runs.
    pub fn completed(&self) -> usize {
        self.records.len()
    }

    /// The journal's file path.
    pub fn path(&self) -> &str {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn temp_path(tag: &str) -> String {
        static N: AtomicUsize = AtomicUsize::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir()
            .join(format!("slfac_journal_{tag}_{}_{n}/journal.jsonl", std::process::id()))
            .to_str()
            .unwrap()
            .to_string()
    }

    fn header() -> JournalHeader {
        JournalHeader {
            sweep: "g".into(),
            fingerprint: "00000000deadbeef".into(),
            grid: 3,
        }
    }

    fn record(run_id: usize) -> RunRecord {
        RunRecord {
            run_id,
            name: format!("g_run{run_id}"),
            axes: BTreeMap::from([("seed".to_string(), Json::Num(run_id as f64))]),
            config_fp: format!("{:016x}", 0xabcu64 + run_id as u64),
            metrics: RunMetrics {
                rounds: 2,
                final_train_loss: 0.5 + run_id as f64,
                final_test_loss: 0.25,
                final_test_acc: 0.75,
                best_test_acc: 0.8,
                uplink_bytes: 1024,
                downlink_bytes: 2048,
                total_bytes: 3072,
                makespan_s: 1.5,
                queue_wait_s: 0.125,
                dropped_devices: 1,
            },
        }
    }

    #[test]
    fn roundtrips_header_and_records() {
        let path = temp_path("roundtrip");
        let mut j = Journal::create(&path, header()).unwrap();
        j.append(record(0)).unwrap();
        j.append(record(1)).unwrap();
        let re = Journal::open(&path).unwrap();
        assert_eq!(re.header(), &header());
        assert_eq!(re.records(), &[record(0), record(1)]);
        assert_eq!(re.completed(), 2);
        // record schema survives a json round-trip exactly
        let back = RunRecord::from_json(&record(0).to_json()).unwrap();
        assert_eq!(back, record(0));
    }

    #[test]
    fn torn_tail_is_ignored_and_truncated_by_append() {
        let path = temp_path("torn");
        let mut j = Journal::create(&path, header()).unwrap();
        j.append(record(0)).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // simulate a crash mid-append: garbage with no trailing newline
        let mut torn = clean.clone();
        torn.extend_from_slice(b"{\"run_id\":1,\"na");
        std::fs::write(&path, &torn).unwrap();
        let mut re = Journal::open(&path).unwrap();
        assert_eq!(re.completed(), 1, "torn tail must not count");
        re.append(record(1)).unwrap();
        let mut want = clean;
        want.extend_from_slice(format!("{}\n", record(1).to_json().to_string()).as_bytes());
        assert_eq!(std::fs::read(&path).unwrap(), want);
    }

    #[test]
    fn rejects_out_of_order_and_bad_schema() {
        let path = temp_path("order");
        let mut j = Journal::create(&path, header()).unwrap();
        let err = j.append(record(1)).unwrap_err();
        assert!(format!("{err:#}").contains("run_id 1"), "{err:#}");
        j.append(record(0)).unwrap();
        // hand-edit the file into an out-of-order state
        let text = std::fs::read_to_string(&path).unwrap();
        let skipped = text.replace("\"run_id\":0", "\"run_id\":2");
        std::fs::write(&path, skipped).unwrap();
        let err = Journal::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("out of order"), "{err:#}");
        // wrong schema id on the header line
        let bad = temp_path("schema");
        std::fs::create_dir_all(std::path::Path::new(&bad).parent().unwrap()).unwrap();
        std::fs::write(&bad, "{\"schema\":\"slfac-sweep-journal/9\"}\n").unwrap();
        let err = Journal::open(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("slfac-sweep-journal/9"), "{err:#}");
    }

    #[test]
    fn create_refuses_to_clobber() {
        let path = temp_path("clobber");
        Journal::create(&path, header()).unwrap();
        assert!(Journal::create(&path, header()).is_err());
        // open_or_create resumes instead
        let j = Journal::open_or_create(&path, header()).unwrap();
        assert_eq!(j.completed(), 0);
    }
}
