//! Phased sweep orchestrator: **plan → execute → report**.
//!
//! Plan expands the grid, opens (or creates) the journal, and
//! cross-checks it against the spec — sweep name, spec fingerprint, grid
//! size, and each journaled record's run name + config fingerprint must
//! match what the spec expands to, so a resumed sweep fails fast instead
//! of silently mixing results from two different grids.
//!
//! Execute dispatches pending runs in **waves** of the worker-pool width
//! through [`run_sharded`] (the same scoped-thread shard discipline as
//! the round engine: each worker owns its slot exclusively; the shared
//! [`ExecutorHandle`] is the only cross-thread state). After the wave
//! barrier, completed runs are journaled **in grid order** — so the
//! journal's bytes are independent of the worker count, and killing the
//! process loses at most the in-flight wave, never reorders records.
//!
//! Report re-reads the journal from disk and writes an unpaginated
//! `slfac-sweep/1` page next to it. Determinism argument: each run's
//! metrics are bit-reproducible at a fixed seed regardless of worker
//! count (the trainer's own differential pin), records serialize floats
//! with the shortest-roundtrip formatter (equal bits ⇒ equal text), and
//! records land in dense grid order — so interrupted+resumed, at any
//! worker counts, is byte-identical to uninterrupted.

use crate::coordinator::{effective_workers, run_sharded, TrainOutcome, Trainer};
use crate::json::Json;
use crate::runtime::{write_sim_manifest, BackendKind, ExecutorHandle};
use crate::sweep::journal::{Journal, JournalHeader, RunMetrics, RunRecord};
use crate::sweep::report;
use crate::sweep::spec::{RunSpec, SweepSpec};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Knobs for one `run_sweep` invocation (not part of the spec: none of
/// these may change results, only where they land and how far they go).
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Sweep-level worker pool override (`None` = the spec's `workers`).
    pub workers: Option<usize>,
    /// Execute at most this many *new* runs, then stop cleanly — the
    /// interruption hook the resume tests and the CI smoke use.
    pub stop_after: Option<usize>,
    /// Results root: the sweep writes under `<out_dir>/<sweep-name>/`.
    pub out_dir: String,
    /// Journal path override (`None` = `<out_dir>/<name>/journal.jsonl`).
    pub journal_path: Option<String>,
    /// Per-run crash-durable checkpoint cadence (`0` = off). When on,
    /// every run checkpoints into
    /// `<out_dir>/<sweep-name>/ckpt/<run-name>/` every this many rounds
    /// and resumes mid-run from the newest checkpoint — so a mid-wave
    /// kill loses at most `checkpoint_every - 1` rounds per in-flight
    /// run, not the whole run. Results (and hence journal + report
    /// bytes) are unaffected: the checkpoint keys are not part of the
    /// config fingerprint, and resume is bit-identical to never having
    /// crashed.
    pub checkpoint_every: usize,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            workers: None,
            stop_after: None,
            out_dir: "results".to_string(),
            journal_path: None,
            checkpoint_every: 0,
        }
    }
}

/// One executed run: its spec plus the full training outcome (history,
/// comm stats). Only runs executed by *this* invocation appear —
/// journaled-and-skipped runs are summarized by their [`RunRecord`]s.
pub struct SweepRunResult {
    /// The expanded run.
    pub run: RunSpec,
    /// The trainer's outcome.
    pub outcome: TrainOutcome,
}

/// What one `run_sweep` invocation did.
pub struct SweepOutcome {
    /// Grid size.
    pub grid: usize,
    /// Runs already journaled before this invocation (skipped).
    pub skipped: usize,
    /// Runs executed by this invocation.
    pub executed: usize,
    /// Runs journaled in total after this invocation.
    pub completed: usize,
    /// True when the sweep stopped (via `stop_after`) before the grid was
    /// exhausted.
    pub interrupted: bool,
    /// Journal path.
    pub journal_path: String,
    /// Report path (written every invocation, partial or not).
    pub report_path: String,
    /// Full outcomes of the runs this invocation executed, in grid order.
    pub results: Vec<SweepRunResult>,
}

/// Where the journal lives for this spec + options.
pub fn journal_path(spec: &SweepSpec, opts: &SweepOptions) -> String {
    match &opts.journal_path {
        Some(p) => p.clone(),
        None => format!("{}/{}/journal.jsonl", opts.out_dir, spec.name),
    }
}

/// The journal header this spec plans to: sweep name, spec fingerprint,
/// grid size.
pub fn planned_header(spec: &SweepSpec) -> JournalHeader {
    JournalHeader {
        sweep: spec.name.clone(),
        fingerprint: spec.fingerprint_hex(),
        grid: spec.grid_size(),
    }
}

/// Cross-check an opened journal against the spec's expansion: header
/// identity plus, per journaled record, the run name and config
/// fingerprint the grid produces at that index.
pub fn verify_journal(spec: &SweepSpec, runs: &[RunSpec], journal: &Journal) -> Result<()> {
    let planned = planned_header(spec);
    let found = journal.header();
    if *found != planned {
        bail!(
            "journal {} belongs to a different sweep: journal has \
             (sweep '{}', fingerprint {}, grid {}), spec expands to \
             (sweep '{}', fingerprint {}, grid {})",
            journal.path(),
            found.sweep,
            found.fingerprint,
            found.grid,
            planned.sweep,
            planned.fingerprint,
            planned.grid
        );
    }
    for rec in journal.records() {
        let run = &runs[rec.run_id];
        let fp = format!("{:016x}", run.cfg.fingerprint());
        if rec.name != run.name || rec.config_fp != fp {
            bail!(
                "journal {} record {}: journaled ('{}', config {}) but the \
                 spec expands run {} to ('{}', config {})",
                journal.path(),
                rec.run_id,
                rec.name,
                rec.config_fp,
                rec.run_id,
                run.name,
                fp
            );
        }
    }
    Ok(())
}

fn record_for(run: &RunSpec, outcome: &TrainOutcome) -> RunRecord {
    let h = &outcome.history;
    let last = h.rounds.last();
    RunRecord {
        run_id: run.run_id,
        name: run.name.clone(),
        axes: run.axes.clone(),
        config_fp: format!("{:016x}", run.cfg.fingerprint()),
        metrics: RunMetrics {
            rounds: h.rounds.len(),
            final_train_loss: last.map(|r| r.train_loss).unwrap_or(0.0),
            final_test_loss: last.map(|r| r.test_loss).unwrap_or(0.0),
            final_test_acc: h.final_test_acc(),
            best_test_acc: h.best_test_acc(),
            uplink_bytes: outcome.comm.uplink_bytes,
            downlink_bytes: outcome.comm.downlink_bytes,
            total_bytes: outcome.comm.uplink_bytes + outcome.comm.downlink_bytes,
            makespan_s: outcome.comm.makespan_s,
            // round-order folds: order-stable, so bit-reproducible
            queue_wait_s: h.rounds.iter().map(|r| r.queue_wait_s).sum(),
            dropped_devices: h.rounds.iter().map(|r| r.dropped_devices).sum(),
        },
    }
}

/// Run (or resume) a sweep. See the module docs for the phase lifecycle
/// and the determinism argument.
pub fn run_sweep(spec: &SweepSpec, opts: &SweepOptions) -> Result<SweepOutcome> {
    // ---- plan ----
    let runs = spec.expand()?;
    let grid = runs.len();
    // one executor serves every run, so they must share an artifacts dir
    let artifacts_dir = runs
        .first()
        .map(|r| r.cfg.artifacts_dir.clone())
        .unwrap_or_default();
    if let Some(odd) = runs.iter().find(|r| r.cfg.artifacts_dir != artifacts_dir) {
        bail!(
            "sweep runs must share one artifacts_dir: run '{}' uses '{}' but \
             run '{}' uses '{}' (set it in `base`, not on an axis)",
            runs[0].name,
            artifacts_dir,
            odd.name,
            odd.cfg.artifacts_dir
        );
    }
    let jpath = journal_path(spec, opts);
    let mut journal = Journal::open_or_create(&jpath, planned_header(spec))?;
    verify_journal(spec, &runs, &journal)?;
    let skipped = journal.completed();

    // ---- execute ----
    let mut next = skipped;
    let mut budget = opts.stop_after;
    let mut results: Vec<SweepRunResult> = Vec::new();
    if next < grid && budget != Some(0) {
        if spec.backend == BackendKind::Sim {
            if let Some(sm) = &spec.sim_manifest {
                let manifest = format!("{artifacts_dir}/manifest.json");
                if !std::path::Path::new(&manifest).exists() {
                    write_sim_manifest(&artifacts_dir, std::slice::from_ref(sm))
                        .context("writing sweep sim manifest")?;
                }
            }
        }
        let presets: BTreeSet<String> = runs
            .iter()
            .map(|r| r.cfg.dataset.name().to_string())
            .collect();
        let presets: Vec<String> = presets.into_iter().collect();
        let exec = ExecutorHandle::spawn_backend(&artifacts_dir, &presets, spec.backend)?;
        let pool = effective_workers(opts.workers.unwrap_or(spec.workers), grid - next);
        while next < grid && budget != Some(0) {
            let mut wave_end = (next + pool).min(grid);
            if let Some(b) = budget {
                wave_end = wave_end.min(next + b);
            }
            // each slot owns its run id, executor clone, and result; the
            // scoped workers touch nothing else
            let mut slots: Vec<(usize, ExecutorHandle, Option<TrainOutcome>)> =
                (next..wave_end).map(|i| (i, exec.clone(), None)).collect();
            let wave_err = run_sharded(&mut slots, pool, |_, slot| {
                let run = &runs[slot.0];
                let mut cfg = run.cfg.clone();
                if opts.checkpoint_every > 0 {
                    // operational knobs only: neither key is serialized, so
                    // the run's config fingerprint — and the journal — are
                    // byte-identical with checkpointing on or off
                    cfg.checkpoint_every = opts.checkpoint_every;
                    cfg.checkpoint_dir =
                        format!("{}/{}/ckpt/{}", opts.out_dir, spec.name, run.name);
                }
                let mut trainer = Trainer::new(cfg, slot.1.clone())
                    .with_context(|| format!("sweep run '{}'", run.name))?;
                if opts.checkpoint_every > 0 {
                    trainer
                        .resume_latest()
                        .with_context(|| format!("resuming sweep run '{}'", run.name))?;
                }
                slot.2 = Some(
                    trainer
                        .run()
                        .with_context(|| format!("sweep run '{}'", run.name))?,
                );
                Ok(())
            });
            // journal strictly in grid order; a failed slot stops the
            // dense prefix so the journal never has holes
            for (i, _, outcome) in slots {
                let Some(outcome) = outcome else { break };
                let run = &runs[i];
                let csv = format!("{}/{}/{}.csv", opts.out_dir, spec.name, run.name);
                outcome
                    .history
                    .write_csv(&csv)
                    .with_context(|| format!("writing {csv}"))?;
                journal.append(record_for(run, &outcome))?;
                next = i + 1;
                if let Some(b) = &mut budget {
                    *b -= 1;
                }
                results.push(SweepRunResult {
                    run: run.clone(),
                    outcome,
                });
            }
            wave_err?;
        }
    }

    // ---- report ----
    // re-read from disk so the report reflects exactly the journaled bytes
    let journal = Journal::open(&jpath)?;
    let doc = report::page(journal.header(), journal.records(), None, 0);
    let report_path = format!("{}/{}/report.json", opts.out_dir, spec.name);
    crate::bench::report::write(&report_path, &doc)
        .with_context(|| format!("writing {report_path}"))?;

    Ok(SweepOutcome {
        grid,
        skipped,
        executed: results.len(),
        completed: journal.completed(),
        interrupted: next < grid,
        journal_path: jpath,
        report_path,
        results,
    })
}

/// Queryable sweep status (`slfac-sweep-status/1`): how much of the grid
/// is journaled, without executing anything. A missing journal is an
/// un-started sweep, not an error.
pub fn sweep_status(spec: &SweepSpec, opts: &SweepOptions) -> Result<Json> {
    let runs = spec.expand()?;
    let jpath = journal_path(spec, opts);
    let completed = if std::path::Path::new(&jpath).exists() {
        let journal = Journal::open(&jpath)?;
        verify_journal(spec, &runs, &journal)?;
        journal.completed()
    } else {
        0
    };
    let mut m = BTreeMap::new();
    m.insert("sweep".to_string(), Json::Str(spec.name.clone()));
    m.insert("fingerprint".to_string(), Json::Str(spec.fingerprint_hex()));
    m.insert("grid".to_string(), Json::Num(runs.len() as f64));
    m.insert("completed".to_string(), Json::Num(completed as f64));
    m.insert("pending".to_string(), Json::Num((runs.len() - completed) as f64));
    m.insert("journal".to_string(), Json::Str(jpath));
    Ok(crate::bench::report::versioned("sweep-status", 1, m))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(text: &str) -> SweepSpec {
        SweepSpec::from_json(&Json::parse(text).unwrap()).unwrap()
    }

    #[test]
    fn journal_path_defaults_under_out_dir() {
        let s = spec(r#"{"name": "g"}"#);
        let opts = SweepOptions::default();
        assert_eq!(journal_path(&s, &opts), "results/g/journal.jsonl");
        let opts = SweepOptions {
            journal_path: Some("elsewhere/j.jsonl".into()),
            ..Default::default()
        };
        assert_eq!(journal_path(&s, &opts), "elsewhere/j.jsonl");
    }

    #[test]
    fn planned_header_pins_spec_identity() {
        let s = spec(r#"{"name": "g", "axes": [{"seed": [1, 2, 3]}]}"#);
        let h = planned_header(&s);
        assert_eq!(h.sweep, "g");
        assert_eq!(h.grid, 3);
        assert_eq!(h.fingerprint, s.fingerprint_hex());
    }

    #[test]
    fn status_of_unstarted_sweep_is_all_pending() {
        let s = spec(r#"{"name": "g_unstarted_nowhere", "axes": [{"seed": [1, 2]}]}"#);
        let opts = SweepOptions {
            out_dir: std::env::temp_dir()
                .join(format!("slfac_sweep_status_{}", std::process::id()))
                .to_str()
                .unwrap()
                .to_string(),
            ..Default::default()
        };
        let st = sweep_status(&s, &opts).unwrap();
        assert_eq!(st.get("completed").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(st.get("pending").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(
            st.get("schema").and_then(|v| v.as_str()),
            Some("slfac-sweep-status/1")
        );
    }

    #[test]
    fn mixed_artifacts_dirs_are_rejected_before_any_io() {
        let s = spec(
            r#"{"name": "g", "axes": [
                {"artifacts_dir": ["a", "b"]}]}"#,
        );
        let opts = SweepOptions {
            out_dir: "/nonexistent-never-created".into(),
            ..Default::default()
        };
        let err = format!("{:#}", run_sweep(&s, &opts).unwrap_err());
        assert!(err.contains("share one artifacts_dir"), "{err}");
        assert!(!std::path::Path::new("/nonexistent-never-created").exists());
    }
}
