//! Declarative sweep grids: [`SweepSpec`] cross-products its axes into
//! concrete [`RunSpec`]s.
//!
//! A spec is a JSON file (see `configs/sweeps/`):
//!
//! ```json
//! {
//!   "name": "fig2",
//!   "base": {"rounds": 15},
//!   "axes": [
//!     {"dataset": ["mnist", "ham"]},
//!     {"codec": ["slfac", {"codec": "tk-sl", "keep_fraction": 0.08}]}
//!   ]
//! }
//! ```
//!
//! `axes` is an **array** of single-key objects so author order survives
//! the order-canonicalizing JSON parser; expansion is row-major with the
//! **last axis fastest**, so consecutive runs form the paper's panel
//! columns. Scalar axis values patch `{key: value}`; object values are
//! multi-key patches applied together (they must set `key` itself, which
//! names the run) — that is how a codec axis carries its byte-parity
//! calibration (`keep_fraction`, `uniform_bits`, …) alongside the codec
//! name. Every expanded config goes through
//! [`ExperimentConfig::from_json`], so key and value errors are named
//! exactly as for a hand-written config file.

use crate::config::ExperimentConfig;
use crate::json::Json;
use crate::runtime::{BackendKind, SimManifestSpec};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// One sweep axis: a config key and the values it takes, in author order.
#[derive(Debug, Clone)]
pub struct Axis {
    /// Config key this axis varies.
    pub key: String,
    /// Values: scalars, or objects carrying a multi-key patch.
    pub values: Vec<Json>,
}

/// A declarative experiment grid, parsed from JSON.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Sweep name: results live under `<out_dir>/<name>/` and every run
    /// name is prefixed with it. Restricted to `[A-Za-z0-9_.-]`.
    pub name: String,
    /// Executor backend every run shares (`xla` default, or `sim`).
    pub backend: BackendKind,
    /// Sweep-level worker pool width — concurrent *runs* (`0` = auto).
    /// Distinct from the per-run `workers` config key (device-parallel
    /// round phases inside one run).
    pub workers: usize,
    /// With `backend = "sim"`: write this sim manifest into the shared
    /// `artifacts_dir` when no `manifest.json` exists there, so a sweep
    /// is self-contained from a scratch directory.
    pub sim_manifest: Option<SimManifestSpec>,
    /// Base experiment config (JSON object) every run starts from.
    pub base: Json,
    /// Axes, outermost first.
    pub axes: Vec<Axis>,
}

/// One concrete run expanded from the grid.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Dense grid index, row-major with the last axis fastest. Doubles as
    /// the journal record id and the pagination key.
    pub run_id: usize,
    /// Generated run name: `<sweep>_<label>_<label>…`.
    pub name: String,
    /// Per-axis label pieces, in axis order (the last one is the panel
    /// column label).
    pub labels: Vec<String>,
    /// Axis key → the scalar value chosen for this run.
    pub axes: BTreeMap<String, Json>,
    /// The fully validated experiment configuration.
    pub cfg: ExperimentConfig,
}

impl SweepSpec {
    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading sweep spec {path}"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing sweep spec {path}"))?;
        Self::from_json(&json).with_context(|| format!("validating sweep spec {path}"))
    }

    /// Build from parsed JSON. Unknown keys are rejected (typo safety),
    /// and every rejection names the offending key and value, matching
    /// the `config.rs` error style.
    pub fn from_json(json: &Json) -> Result<Self> {
        let obj = json.as_obj().context("sweep spec root must be an object")?;
        let mut name: Option<String> = None;
        let mut backend = BackendKind::Xla;
        let mut workers = 0usize;
        let mut sim_manifest: Option<SimManifestSpec> = None;
        let mut base = Json::Obj(BTreeMap::new());
        let mut axes: Vec<Axis> = Vec::new();
        for (key, v) in obj {
            match key.as_str() {
                "name" => name = Some(v.as_str().context("name: string")?.to_string()),
                "backend" => {
                    backend = match v.as_str().context("backend: string")? {
                        "xla" => BackendKind::Xla,
                        "sim" => BackendKind::Sim,
                        other => bail!("unknown backend '{other}' (expected xla | sim)"),
                    }
                }
                "workers" => workers = v.as_usize().context("workers")?,
                "sim_manifest" => sim_manifest = Some(parse_sim_manifest(v)?),
                "base" => {
                    v.as_obj().context("base: object")?;
                    base = v.clone();
                }
                "axes" => axes = parse_axes(v)?,
                other => bail!("unknown sweep key '{other}'"),
            }
        }
        let name = name.context("sweep spec needs a 'name' key")?;
        if name.is_empty() || !name.chars().all(path_safe) {
            bail!(
                "sweep name '{name}' must be non-empty and contain only \
                 letters, digits, '_', '.', '-' (it becomes a directory name)"
            );
        }
        if sim_manifest.is_some() && backend != BackendKind::Sim {
            bail!("sim_manifest requires backend = \"sim\", got backend = \"xla\"");
        }
        Ok(SweepSpec {
            name,
            backend,
            workers,
            sim_manifest,
            base,
            axes,
        })
    }

    /// Grid size: the product of axis lengths (1 when there are no axes —
    /// the base config alone).
    pub fn grid_size(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    /// Canonical serialization (status output + fingerprinting).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert(
            "backend".to_string(),
            Json::Str(
                match self.backend {
                    BackendKind::Xla => "xla",
                    BackendKind::Sim => "sim",
                }
                .into(),
            ),
        );
        m.insert("workers".to_string(), Json::Num(self.workers as f64));
        if let Some(sm) = &self.sim_manifest {
            let mut s = BTreeMap::new();
            s.insert("preset".to_string(), Json::Str(sm.preset.clone()));
            s.insert("batch_size".to_string(), Json::Num(sm.batch_size as f64));
            s.insert(
                "act_channels".to_string(),
                Json::Num(sm.act_channels as f64),
            );
            s.insert("act_hw".to_string(), Json::Num(sm.act_hw as f64));
            m.insert("sim_manifest".to_string(), Json::Obj(s));
        }
        m.insert("base".to_string(), self.base.clone());
        m.insert(
            "axes".to_string(),
            Json::Arr(
                self.axes
                    .iter()
                    .map(|a| {
                        Json::Obj(BTreeMap::from([(
                            a.key.clone(),
                            Json::Arr(a.values.clone()),
                        )]))
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Stable hex fingerprint of the canonical spec serialization. The
    /// journal header pins it, so a resumed sweep detects spec drift
    /// before touching any run.
    pub fn fingerprint_hex(&self) -> String {
        format!("{:016x}", self.to_json().fingerprint())
    }

    /// Cross-product the axes into concrete runs, row-major with the last
    /// axis fastest. Each run's config is `base` ⊕ axis patches ⊕ the
    /// generated run name, then parsed and validated by
    /// [`ExperimentConfig::from_json`].
    pub fn expand(&self) -> Result<Vec<RunSpec>> {
        let total = self.grid_size();
        let mut runs = Vec::with_capacity(total);
        let mut names: BTreeMap<String, usize> = BTreeMap::new();
        for run_id in 0..total {
            // decode the mixed-radix grid index, last axis fastest
            let mut picks = vec![0usize; self.axes.len()];
            let mut rem = run_id;
            for (ai, axis) in self.axes.iter().enumerate().rev() {
                picks[ai] = rem % axis.values.len();
                rem /= axis.values.len();
            }
            let mut doc = self.base.clone();
            let mut labels = Vec::with_capacity(self.axes.len());
            let mut chosen = BTreeMap::new();
            for (axis, &pi) in self.axes.iter().zip(&picks) {
                let val = &axis.values[pi];
                let patch = match val {
                    Json::Obj(_) => val.clone(),
                    scalar => {
                        Json::Obj(BTreeMap::from([(axis.key.clone(), scalar.clone())]))
                    }
                };
                doc = doc
                    .overlaid(&patch)
                    .expect("base and axis patches are objects (validated at parse)");
                labels.push(value_label(&axis.key, val)?);
                let scalar = match val {
                    Json::Obj(m) => m.get(&axis.key).expect("validated at parse").clone(),
                    s => s.clone(),
                };
                chosen.insert(axis.key.clone(), scalar);
            }
            let run_name = if labels.is_empty() {
                format!("{}_base", self.name)
            } else {
                format!("{}_{}", self.name, labels.join("_"))
            };
            let name_patch =
                Json::Obj(BTreeMap::from([("name".to_string(), Json::Str(run_name.clone()))]));
            doc = doc.overlaid(&name_patch).expect("doc is an object");
            let cfg = ExperimentConfig::from_json(&doc)
                .with_context(|| format!("sweep run '{run_name}' (run {run_id} of {total})"))?;
            if let Some(prev) = names.insert(run_name.clone(), run_id) {
                bail!(
                    "runs {prev} and {run_id} are both labelled '{run_name}' — \
                     distinct axis values collide after label sanitizing"
                );
            }
            runs.push(RunSpec {
                run_id,
                name: run_name,
                labels,
                axes: chosen,
                cfg,
            });
        }
        Ok(runs)
    }
}

fn path_safe(c: char) -> bool {
    c.is_ascii_alphanumeric() || matches!(c, '_' | '.' | '-')
}

fn sanitize(s: &str) -> String {
    s.chars().map(|c| if path_safe(c) { c } else { '-' }).collect()
}

/// The label piece an axis value contributes to the run name. Object
/// values must set the axis key itself; its scalar names the run. Strings
/// label as themselves (`slfac`), numbers as `<key><value>` (`theta0.5`),
/// bools as `<key>-<value>`.
fn value_label(key: &str, val: &Json) -> Result<String> {
    let scalar = match val {
        Json::Obj(m) => m.get(key).with_context(|| {
            format!(
                "axis '{key}': an object value must set the '{key}' key itself \
                 (it names the run)"
            )
        })?,
        other => other,
    };
    let raw = match scalar {
        Json::Str(s) => s.clone(),
        Json::Num(v) => {
            let text = Json::Num(*v).to_string(); // shortest-roundtrip, int-aware
            format!("{key}{text}")
        }
        Json::Bool(b) => format!("{key}-{b}"),
        other => bail!(
            "axis '{key}': values must be strings, numbers, bools, or patch \
             objects, got {}",
            kind_name(other)
        ),
    };
    Ok(sanitize(&raw))
}

fn kind_name(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "a bool",
        Json::Num(_) => "a number",
        Json::Str(_) => "a string",
        Json::Arr(_) => "an array",
        Json::Obj(_) => "an object",
    }
}

fn parse_axes(v: &Json) -> Result<Vec<Axis>> {
    let arr = v
        .as_arr()
        .context("axes: array of single-key objects like {\"codec\": [...]}")?;
    let mut axes: Vec<Axis> = Vec::new();
    for (i, item) in arr.iter().enumerate() {
        let obj = item
            .as_obj()
            .with_context(|| format!("axes[{i}] must be a single-key object"))?;
        if obj.len() != 1 {
            bail!(
                "axes[{i}] must have exactly one key (the config key it varies), \
                 got {} keys",
                obj.len()
            );
        }
        let (key, values) = obj.iter().next().expect("len == 1");
        let values = values
            .as_arr()
            .with_context(|| format!("axis '{key}': values must be an array"))?;
        if values.is_empty() {
            bail!("axis '{key}' has no values");
        }
        if axes.iter().any(|a| a.key == *key) {
            bail!("duplicate axis '{key}'");
        }
        let mut labels: Vec<String> = Vec::with_capacity(values.len());
        for (j, val) in values.iter().enumerate() {
            let label = value_label(key, val).with_context(|| format!("axis '{key}' value {j}"))?;
            if labels.contains(&label) {
                bail!("axis '{key}' repeats the value labelled '{label}'");
            }
            labels.push(label);
        }
        axes.push(Axis {
            key: key.clone(),
            values: values.to_vec(),
        });
    }
    Ok(axes)
}

fn parse_sim_manifest(v: &Json) -> Result<SimManifestSpec> {
    let obj = v.as_obj().context("sim_manifest: object")?;
    let mut spec = SimManifestSpec {
        preset: "mnist".into(),
        batch_size: 8,
        act_channels: 2,
        act_hw: 4,
    };
    for (key, v) in obj {
        match key.as_str() {
            "preset" => {
                spec.preset = v.as_str().context("sim_manifest.preset: string")?.to_string()
            }
            "batch_size" => spec.batch_size = v.as_usize().context("sim_manifest.batch_size")?,
            "act_channels" => {
                spec.act_channels = v.as_usize().context("sim_manifest.act_channels")?
            }
            "act_hw" => spec.act_hw = v.as_usize().context("sim_manifest.act_hw")?,
            other => bail!("unknown sim_manifest key '{other}'"),
        }
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(text: &str) -> Result<SweepSpec> {
        SweepSpec::from_json(&Json::parse(text).unwrap())
    }

    #[test]
    fn minimal_spec_expands_to_base() {
        let s = spec(r#"{"name": "solo"}"#).unwrap();
        assert_eq!(s.grid_size(), 1);
        let runs = s.expand().unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].name, "solo_base");
        assert_eq!(runs[0].cfg.name, "solo_base");
        assert_eq!(runs[0].cfg.codec, "slfac"); // defaults fill in
    }

    #[test]
    fn expansion_is_row_major_last_axis_fastest() {
        let s = spec(
            r#"{"name": "g",
                "axes": [{"codec": ["slfac", "pq-sl"]}, {"seed": [7, 9]}]}"#,
        )
        .unwrap();
        let runs = s.expand().unwrap();
        assert_eq!(runs.len(), 4);
        let names: Vec<&str> = runs.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(
            names,
            ["g_slfac_seed7", "g_slfac_seed9", "g_pq-sl_seed7", "g_pq-sl_seed9"]
        );
        assert_eq!(runs[2].cfg.codec, "pq-sl");
        assert_eq!(runs[2].cfg.seed, 7);
        assert_eq!(runs[3].cfg.seed, 9);
        // run_id is the dense index and the seed axis landed in `axes`
        for (i, r) in runs.iter().enumerate() {
            assert_eq!(r.run_id, i);
            assert!(r.axes.contains_key("codec") && r.axes.contains_key("seed"));
        }
    }

    #[test]
    fn object_values_patch_multiple_keys() {
        let s = spec(
            r#"{"name": "g", "axes": [
                {"codec": ["slfac",
                           {"codec": "tk-sl", "keep_fraction": 0.08,
                            "random_fraction": 0.02}]}]}"#,
        )
        .unwrap();
        let runs = s.expand().unwrap();
        assert_eq!(runs[1].name, "g_tk-sl");
        assert_eq!(runs[1].cfg.codec, "tk-sl");
        assert!((runs[1].cfg.codec_params.keep_fraction - 0.08).abs() < 1e-12);
        assert!((runs[1].cfg.codec_params.random_fraction - 0.02).abs() < 1e-12);
        // the slfac run keeps the defaults
        assert!((runs[0].cfg.codec_params.keep_fraction
            - crate::codec::CodecParams::default().keep_fraction)
            .abs()
            < 1e-12);
    }

    #[test]
    fn base_seeds_every_run_and_axes_override_it() {
        let s = spec(
            r#"{"name": "g", "base": {"rounds": 3, "seed": 42},
                "axes": [{"seed": [7, 42]}]}"#,
        )
        .unwrap();
        let runs = s.expand().unwrap();
        assert_eq!(runs[0].cfg.rounds, 3);
        assert_eq!(runs[0].cfg.seed, 7);
        // codec params inherit the per-run seed (from_json contract)
        assert_eq!(runs[0].cfg.codec_params.seed, 7);
        assert_eq!(runs[1].cfg.seed, 42);
    }

    #[test]
    fn errors_name_key_and_value() {
        for (bad, needle) in [
            (r#"{"name": "g", "axez": []}"#, "axez"),
            (r#"{"base": {}}"#, "name"),
            (r#"{"name": "a b"}"#, "a b"),
            (r#"{"name": "g", "backend": "tpu"}"#, "tpu"),
            (r#"{"name": "g", "sim_manifest": {}}"#, "sim_manifest"),
            (r#"{"name": "g", "axes": [{"codec": []}]}"#, "axis 'codec' has no values"),
            (
                r#"{"name": "g", "axes": [{"codec": ["a"], "seed": [1]}]}"#,
                "exactly one key",
            ),
            (
                r#"{"name": "g", "axes": [{"seed": [1]}, {"seed": [2]}]}"#,
                "duplicate axis 'seed'",
            ),
            (
                r#"{"name": "g", "axes": [{"seed": [1, 1]}]}"#,
                "repeats the value",
            ),
            (
                r#"{"name": "g", "axes": [{"codec": [{"keep_fraction": 0.5}]}]}"#,
                "must set the 'codec' key",
            ),
            (r#"{"name": "g", "axes": [{"codec": [null]}]}"#, "null"),
            // config-level validation flows through with the run context
            (r#"{"name": "g", "axes": [{"theta": [1.5]}]}"#, "theta"),
            (r#"{"name": "g", "base": {"codek": "slfac"}}"#, "codek"),
        ] {
            let err = match spec(bad) {
                Err(e) => format!("{e:#}"),
                Ok(s) => match s.expand() {
                    Err(e) => format!("{e:#}"),
                    Ok(_) => panic!("should reject {bad}"),
                },
            };
            assert!(err.contains(needle), "error for {bad} should name '{needle}': {err}");
        }
    }

    #[test]
    fn expand_error_names_the_run() {
        let s = spec(r#"{"name": "g", "axes": [{"theta": [0.9, 1.5]}]}"#).unwrap();
        let err = format!("{:#}", s.expand().unwrap_err());
        assert!(err.contains("g_theta1.5"), "{err}");
        assert!(err.contains("run 1 of 2"), "{err}");
    }

    #[test]
    fn fingerprint_pins_the_whole_spec() {
        let a = spec(r#"{"name": "g", "axes": [{"seed": [1, 2]}]}"#).unwrap();
        let b = spec(r#"{"name": "g", "axes": [{"seed": [1, 2]}]}"#).unwrap();
        assert_eq!(a.fingerprint_hex(), b.fingerprint_hex());
        let c = spec(r#"{"name": "g", "axes": [{"seed": [1, 3]}]}"#).unwrap();
        assert_ne!(a.fingerprint_hex(), c.fingerprint_hex());
        let d = spec(r#"{"name": "g", "base": {"rounds": 9}, "axes": [{"seed": [1, 2]}]}"#)
            .unwrap();
        assert_ne!(a.fingerprint_hex(), d.fingerprint_hex());
        assert_eq!(a.fingerprint_hex().len(), 16);
    }

    #[test]
    fn sim_manifest_requires_sim_backend_and_parses() {
        let s = spec(
            r#"{"name": "g", "backend": "sim",
                "sim_manifest": {"preset": "mnist", "batch_size": 8,
                                 "act_channels": 2, "act_hw": 4}}"#,
        )
        .unwrap();
        let sm = s.sim_manifest.unwrap();
        assert_eq!(sm.preset, "mnist");
        assert_eq!((sm.batch_size, sm.act_channels, sm.act_hw), (8, 2, 4));
        let err = spec(r#"{"name": "g", "sim_manifest": {"preset": "mnist"}}"#).unwrap_err();
        assert!(format!("{err:#}").contains("backend"), "{err:#}");
        let err = spec(r#"{"name": "g", "backend": "sim", "sim_manifest": {"presett": "x"}}"#)
            .unwrap_err();
        assert!(format!("{err:#}").contains("presett"), "{err:#}");
    }

    #[test]
    fn label_sanitizing_collisions_are_rejected() {
        // 'a/b' and 'a-b' both sanitize to 'a-b' — ambiguous run names
        let s = spec(r#"{"name": "g", "axes": [{"profile": ["wifi/lte", "wifi-lte"]}]}"#);
        let err = format!("{:#}", s.unwrap_err());
        assert!(err.contains("repeats the value"), "{err}");
    }

    #[test]
    fn shipped_sweep_specs_validate_and_expand() {
        let mut seen = 0;
        for entry in std::fs::read_dir("configs/sweeps").expect("configs/sweeps/ exists") {
            let p = entry.unwrap().path();
            if p.extension().is_some_and(|e| e == "json") {
                let s = SweepSpec::load(p.to_str().unwrap())
                    .unwrap_or_else(|e| panic!("{}: {e:#}", p.display()));
                let runs = s
                    .expand()
                    .unwrap_or_else(|e| panic!("{}: {e:#}", p.display()));
                assert!(!runs.is_empty(), "{}: empty grid", p.display());
                seen += 1;
            }
        }
        assert!(seen >= 5, "expected the shipped sweep specs, found {seen}");
    }
}
