//! Minimal JSON parser + writer.
//!
//! Offline environment ⇒ no `serde`. This module provides the small JSON
//! surface the project needs: parsing artifact manifests emitted by
//! `python/compile/aot.py`, experiment configs in `configs/`, and golden
//! test vectors; writing result/metric files. It is a strict-enough
//! recursive-descent parser (UTF-8, `\uXXXX` escapes, nesting-depth cap) —
//! not a streaming parser; inputs here are ≤ a few MB.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (ints up to 2^53 round-trip).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (order-stable via BTreeMap)
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// Byte offset of the error.
    pub at: usize,
    /// Human-readable message.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError {
            at: self.i,
            msg: msg.into(),
        })
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return self.err("nesting too deep");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit(b"true", Json::Bool(true)),
            Some(b'f') => self.lit(b"false", Json::Bool(false)),
            Some(b'n') => self.lit(b"null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte 0x{c:02x}")),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &[u8], v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err("invalid literal")
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            let v = self.value(depth + 1)?;
            out.push(v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or(JsonError {
                        at: self.i,
                        msg: "bad escape".into(),
                    })?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.s[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            match ch {
                                Some(c) => out.push(c),
                                None => return self.err("invalid \\u escape"),
                            }
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(_) => {
                    // copy one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.s[start]);
                    if start + len > self.s.len() {
                        return self.err("truncated utf-8");
                    }
                    match std::str::from_utf8(&self.s[start..start + len]) {
                        Ok(chunk) => out.push_str(chunk),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.s.len() {
            return self.err("short \\u escape");
        }
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.s[self.i];
            self.i += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return self.err("bad hex digit"),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        match text.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err(format!("bad number '{text}'")),
        }
    }
}

/// 64-bit FNV-1a hash — the cheap, dependency-free stable digest used for
/// canonical-JSON fingerprints ([`Json::fingerprint`]).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            s: text.as_bytes(),
            i: 0,
        };
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.s.len() {
            return p.err("trailing data");
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// As usize (rejects negatives / non-integers).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as usize),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Shallow object overlay: `self` with every top-level entry of
    /// `patch` inserted (replacing colliding keys). Returns `None` unless
    /// both values are objects. The sweep expander uses this to stamp an
    /// axis patch onto a base experiment config.
    pub fn overlaid(&self, patch: &Json) -> Option<Json> {
        match (self, patch) {
            (Json::Obj(base), Json::Obj(p)) => {
                let mut m = base.clone();
                for (k, v) in p {
                    m.insert(k.clone(), v.clone());
                }
                Some(Json::Obj(m))
            }
            _ => None,
        }
    }

    /// 64-bit FNV-1a over the compact serialization. Equal values have
    /// equal serializations (`BTreeMap` key order, shortest-roundtrip
    /// float formatting), so equal values ⇒ equal fingerprints; the sweep
    /// journal pins these to detect spec/config drift across restarts.
    pub fn fingerprint(&self) -> u64 {
        fnv1a64(self.to_string().as_bytes())
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9e15 {
                    out.push_str(&format!("{}", *v as i64));
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".into())
        );
        // surrogate pair: 😀
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"t":true,"s":"hi\n"},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn depth_cap() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn integers_serialize_without_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld"));
    }

    #[test]
    fn overlay_replaces_and_keeps() {
        let base = Json::parse(r#"{"a": 1, "b": 2}"#).unwrap();
        let patch = Json::parse(r#"{"b": 9, "c": 3}"#).unwrap();
        let out = base.overlaid(&patch).unwrap();
        assert_eq!(out.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(out.get("b").unwrap().as_usize(), Some(9));
        assert_eq!(out.get("c").unwrap().as_usize(), Some(3));
        // shallow: nested objects are replaced wholesale, not merged
        let base = Json::parse(r#"{"o": {"x": 1, "y": 2}}"#).unwrap();
        let patch = Json::parse(r#"{"o": {"x": 7}}"#).unwrap();
        let out = base.overlaid(&patch).unwrap();
        assert_eq!(out.get("o"), patch.get("o"));
        // non-objects refuse
        assert!(Json::Num(1.0).overlaid(&patch).is_none());
        assert!(base.overlaid(&Json::Null).is_none());
    }

    #[test]
    fn fingerprint_is_stable_and_discriminating() {
        // FNV-1a offset basis for empty input — pins the constant
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        let a = Json::parse(r#"{"x": 1, "y": 2}"#).unwrap();
        // key order cannot matter: BTreeMap canonicalizes
        let b = Json::parse(r#"{"y": 2, "x": 1}"#).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = Json::parse(r#"{"x": 1, "y": 3}"#).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
    }
}
