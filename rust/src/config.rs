//! Experiment configuration: typed schema + JSON loading + validation.
//!
//! Config files live in `configs/` (see the presets there). Everything an
//! experiment needs is in one file — dataset, partition, codec and its
//! hyper-parameters, training schedule, link model, seeds — so a result CSV
//! can always be traced back to an exact configuration.

use crate::codec::CodecParams;
use crate::json::Json;
use crate::transport::{
    ClientSampling, DownlinkMode, FaultConfig, LinkConfig, SchedulerKind, StragglerPolicy,
    UplinkMode,
};
use anyhow::{bail, Context, Result};

/// Which dataset preset to use (selects the artifact set too).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// MNIST-like 1×28×28, 10 classes.
    Mnist,
    /// HAM10000-like 3×32×32, 7 classes.
    Ham,
}

impl DatasetKind {
    /// Parse from a config string.
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mnist" | "mnist_like" => Ok(DatasetKind::Mnist),
            "ham" | "ham10000" | "ham_like" => Ok(DatasetKind::Ham),
            other => bail!("unknown dataset '{other}'"),
        }
    }

    /// Stable name (artifact subdirectory).
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Mnist => "mnist",
            DatasetKind::Ham => "ham",
        }
    }
}

/// Device data distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Shuffle + even split.
    Iid,
    /// Dirichlet with concentration β.
    Dirichlet(f64),
}

/// Client sub-model synchronization protocol across devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// SplitFed-style: devices train in parallel each round, client-side
    /// weights are FedAvg'd at round end (default).
    ParallelFedAvg,
    /// Vanilla sequential SL: devices take turns within a round, weights
    /// hand off from one device to the next.
    Sequential,
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Experiment name (used for the results directory).
    pub name: String,
    /// Dataset preset.
    pub dataset: DatasetKind,
    /// Train/test sample counts and noise for the synthetic generators.
    pub train_samples: usize,
    /// Test split size.
    pub test_samples: usize,
    /// Pixel noise std.
    pub noise: f32,
    /// Number of edge devices (paper: 5).
    pub devices: usize,
    /// Worker threads for the device-parallel round phases (`0` = one per
    /// available CPU). Affects wall-clock only: results are bit-identical
    /// for every value (see `coordinator::engine`).
    pub workers: usize,
    /// IID or Dirichlet(β).
    pub partition: Partition,
    /// Client weight sync protocol.
    pub sync: SyncMode,
    /// Codec name (see [`crate::codec::by_name`]).
    pub codec: String,
    /// Codec hyper-parameters.
    pub codec_params: CodecParams,
    /// Communication rounds to run.
    pub rounds: usize,
    /// Local batches per device per round.
    pub batches_per_round: usize,
    /// Batch size (must match the AOT artifacts).
    pub batch_size: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// SGD momentum.
    pub momentum: f32,
    /// Link model shared by all device links (the `"config"` profile; a
    /// non-default `profile` spec overrides bandwidth/latency per device
    /// but keeps this `jitter`).
    pub link: LinkConfig,
    /// Round scheduler: barriered `sync` (default) or event-driven
    /// `async` (server consumes uplinks as they land).
    pub scheduler: SchedulerKind,
    /// Device profile spec: `"config"` (homogeneous, default) or a link
    /// class mix like `"wifi/lte"` — see [`crate::transport::profile`].
    pub profile: String,
    /// Straggler policy for async rounds (`wait-all` default).
    pub straggler: StragglerPolicy,
    /// Uplink contention model: `private` per-device pipes (default) or
    /// one `shared` pipe concurrent transfers split fairly.
    pub uplink: UplinkMode,
    /// Capacity of the shared uplink pipe in bits/s; `None` inherits the
    /// base link's `uplink_mbps`. Only meaningful with `uplink = shared`.
    pub shared_uplink_bps: Option<f64>,
    /// Downlink contention model: `private` per-device pipes (default) or
    /// one `shared` server-egress pipe whose concurrent broadcasts split
    /// the capacity fairly (the mirror image of `uplink`).
    pub downlink: DownlinkMode,
    /// Capacity of the shared downlink pipe in bits/s; `None` inherits
    /// the base link's `downlink_mbps`. Only meaningful with
    /// `downlink = shared`.
    pub shared_downlink_bps: Option<f64>,
    /// Cohort count for fleet-scale rounds: `0` (default) runs the
    /// per-device scheduler paths; any positive value switches both
    /// schedulers to cohort-compressed control flow that is bit-identical
    /// to the per-device paths (the value only sizes the event-grouping
    /// table — match it to the number of distinct device profiles). Falls
    /// back to the per-device paths under shared uplink/downlink pipes,
    /// whose flow bookkeeping is inherently per-device.
    pub cohorts: usize,
    /// Simulated seconds one batch occupies the server (uplinks queue for
    /// this serial resource; `0` = infinitely fast server, the default).
    pub server_service_s: f64,
    /// Per-round client sampling (`sample_fraction` / `sample_k` keys;
    /// default: every device participates every round).
    pub sampling: ClientSampling,
    /// Fault injection knobs (`loss_prob` / `corrupt_prob` / `crash_rate`
    /// / `max_retries` / `retry_base_s` / `server_outage_s`; all defaults
    /// = fault layer off, bit-identical to the pre-fault engine).
    pub fault: FaultConfig,
    /// Simulated client compute seconds per fan-out/fan-in phase on a
    /// reference (multiplier 1.0) device.
    pub base_compute_s: f64,
    /// Master seed.
    pub seed: u64,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    /// Whether gradients (downlink) are compressed too (paper: yes).
    pub compress_gradients: bool,
    /// Use the planned zero-allocation compute backend (blocked GEMM
    /// kernels + device-resident model state) on backends that support it
    /// (default). `false` routes model compute through the artifact
    /// `execute` path with the reference kernels — results are
    /// **bit-identical** either way (see ARCHITECTURE.md "Compute hot
    /// path"); the toggle exists for debugging and differential testing.
    pub compute_fast_path: bool,
    /// Checkpoint cadence: write a crash-durable training snapshot every
    /// this many completed rounds (`0` = checkpointing off, the default).
    /// Operational knob like `artifacts_dir`: **not** serialized by
    /// `to_json`, so it never perturbs fingerprints or sweep journals.
    pub checkpoint_every: usize,
    /// Directory for checkpoint files. Required iff `checkpoint_every > 0`
    /// (both-or-neither — validated). Not serialized, like
    /// `artifacts_dir`.
    pub checkpoint_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            dataset: DatasetKind::Mnist,
            train_samples: 4000,
            test_samples: 800,
            noise: 0.20,
            devices: 5,
            workers: 0,
            partition: Partition::Iid,
            sync: SyncMode::ParallelFedAvg,
            codec: "slfac".into(),
            codec_params: CodecParams::default(),
            rounds: 15,
            batches_per_round: 10,
            batch_size: 32,
            lr: 0.05,
            momentum: 0.9,
            link: LinkConfig::default(),
            scheduler: SchedulerKind::Sync,
            profile: "config".into(),
            straggler: StragglerPolicy::WaitAll,
            uplink: UplinkMode::Private,
            shared_uplink_bps: None,
            downlink: DownlinkMode::Private,
            shared_downlink_bps: None,
            cohorts: 0,
            server_service_s: 0.0,
            sampling: ClientSampling::Full,
            fault: FaultConfig::default(),
            base_compute_s: 0.002,
            seed: 1234,
            artifacts_dir: "artifacts".into(),
            compress_gradients: true,
            compute_fast_path: true,
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON file. Unknown keys are rejected (typo safety).
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing config {path}"))?;
        Self::from_json(&json).with_context(|| format!("validating config {path}"))
    }

    /// Build from parsed JSON (defaults fill missing keys).
    pub fn from_json(json: &Json) -> Result<Self> {
        let obj = json.as_obj().context("config root must be an object")?;
        let mut cfg = ExperimentConfig::default();
        // straggler/sampling parts may arrive in any key order; build
        // after the loop
        let mut straggler_name: Option<String> = None;
        let mut deadline_s: Option<f64> = None;
        let mut quorum_k: Option<usize> = None;
        let mut sample_fraction: Option<f64> = None;
        let mut sample_k: Option<usize> = None;
        for (key, v) in obj {
            match key.as_str() {
                "name" => cfg.name = v.as_str().context("name: string")?.to_string(),
                "dataset" => {
                    cfg.dataset = DatasetKind::parse(v.as_str().context("dataset: string")?)?
                }
                "train_samples" => cfg.train_samples = v.as_usize().context("train_samples")?,
                "test_samples" => cfg.test_samples = v.as_usize().context("test_samples")?,
                "noise" => cfg.noise = v.as_f64().context("noise")? as f32,
                "devices" => cfg.devices = v.as_usize().context("devices")?,
                "workers" => cfg.workers = v.as_usize().context("workers")?,
                "partition" => {
                    let s = v.as_str().context("partition: string")?;
                    cfg.partition = match s.to_ascii_lowercase().as_str() {
                        "iid" => Partition::Iid,
                        "dirichlet" | "non-iid" | "noniid" => Partition::Dirichlet(0.5),
                        other => bail!("unknown partition '{other}'"),
                    };
                }
                "dirichlet_beta" => {
                    let beta = v.as_f64().context("dirichlet_beta")?;
                    cfg.partition = Partition::Dirichlet(beta);
                }
                "sync" => {
                    let s = v.as_str().context("sync: string")?;
                    cfg.sync = match s.to_ascii_lowercase().as_str() {
                        "parallel" | "fedavg" | "splitfed" => SyncMode::ParallelFedAvg,
                        "sequential" | "vanilla" => SyncMode::Sequential,
                        other => bail!("unknown sync mode '{other}'"),
                    };
                }
                "codec" => cfg.codec = v.as_str().context("codec: string")?.to_string(),
                "theta" => cfg.codec_params.theta = v.as_f64().context("theta")?,
                "b_min" => cfg.codec_params.b_min = v.as_usize().context("b_min")? as u32,
                "b_max" => cfg.codec_params.b_max = v.as_usize().context("b_max")? as u32,
                "uniform_bits" => {
                    cfg.codec_params.uniform_bits = v.as_usize().context("uniform_bits")? as u32
                }
                "keep_fraction" => {
                    cfg.codec_params.keep_fraction = v.as_f64().context("keep_fraction")?
                }
                "random_fraction" => {
                    cfg.codec_params.random_fraction = v.as_f64().context("random_fraction")?
                }
                "drop_threshold" => {
                    cfg.codec_params.drop_threshold = v.as_f64().context("drop_threshold")?
                }
                "subspace_fraction" => {
                    cfg.codec_params.subspace_fraction =
                        v.as_f64().context("subspace_fraction")?
                }
                "codec_fast_path" => {
                    cfg.codec_params.fast_path = v.as_bool().context("codec_fast_path")?
                }
                "rounds" => cfg.rounds = v.as_usize().context("rounds")?,
                "batches_per_round" => {
                    cfg.batches_per_round = v.as_usize().context("batches_per_round")?
                }
                "batch_size" => cfg.batch_size = v.as_usize().context("batch_size")?,
                "lr" => cfg.lr = v.as_f64().context("lr")? as f32,
                "momentum" => cfg.momentum = v.as_f64().context("momentum")? as f32,
                "uplink_mbps" => {
                    cfg.link.uplink_bps = v.as_f64().context("uplink_mbps")? * 1e6
                }
                "downlink_mbps" => {
                    cfg.link.downlink_bps = v.as_f64().context("downlink_mbps")? * 1e6
                }
                "latency_ms" => {
                    cfg.link.latency_s = v.as_f64().context("latency_ms")? / 1000.0
                }
                "jitter" => cfg.link.jitter = v.as_f64().context("jitter")?,
                "scheduler" => {
                    cfg.scheduler = SchedulerKind::parse(v.as_str().context("scheduler: string")?)?
                }
                "profile" => cfg.profile = v.as_str().context("profile: string")?.to_string(),
                "straggler" => {
                    straggler_name = Some(v.as_str().context("straggler: string")?.to_string())
                }
                "deadline_s" => deadline_s = Some(v.as_f64().context("deadline_s")?),
                "quorum_k" => quorum_k = Some(v.as_usize().context("quorum_k")?),
                "uplink" => {
                    cfg.uplink = UplinkMode::parse(v.as_str().context("uplink: string")?)?
                }
                "shared_uplink_mbps" => {
                    cfg.shared_uplink_bps =
                        Some(v.as_f64().context("shared_uplink_mbps")? * 1e6)
                }
                "downlink" => {
                    cfg.downlink = DownlinkMode::parse(v.as_str().context("downlink: string")?)?
                }
                "shared_downlink_mbps" => {
                    cfg.shared_downlink_bps =
                        Some(v.as_f64().context("shared_downlink_mbps")? * 1e6)
                }
                "cohorts" => cfg.cohorts = v.as_usize().context("cohorts")?,
                "server_service_s" => {
                    cfg.server_service_s = v.as_f64().context("server_service_s")?
                }
                "sample_fraction" => {
                    sample_fraction = Some(v.as_f64().context("sample_fraction")?)
                }
                "sample_k" => sample_k = Some(v.as_usize().context("sample_k")?),
                "loss_prob" => cfg.fault.loss_prob = v.as_f64().context("loss_prob")?,
                "corrupt_prob" => cfg.fault.corrupt_prob = v.as_f64().context("corrupt_prob")?,
                "crash_rate" => cfg.fault.crash_rate = v.as_f64().context("crash_rate")?,
                "max_retries" => {
                    cfg.fault.max_retries = v.as_usize().context("max_retries")? as u32
                }
                "retry_base_s" => cfg.fault.retry_base_s = v.as_f64().context("retry_base_s")?,
                "server_outage_s" => {
                    cfg.fault.server_outage_s = v.as_f64().context("server_outage_s")?
                }
                "base_compute_s" => {
                    cfg.base_compute_s = v.as_f64().context("base_compute_s")?
                }
                "seed" => cfg.seed = v.as_f64().context("seed")? as u64,
                "artifacts_dir" => {
                    cfg.artifacts_dir = v.as_str().context("artifacts_dir")?.to_string()
                }
                "compress_gradients" => {
                    cfg.compress_gradients = v.as_bool().context("compress_gradients")?
                }
                "compute_fast_path" => {
                    cfg.compute_fast_path = v.as_bool().context("compute_fast_path")?
                }
                "checkpoint_every" => {
                    cfg.checkpoint_every = v.as_usize().context("checkpoint_every")?
                }
                "checkpoint_dir" => {
                    cfg.checkpoint_dir = v.as_str().context("checkpoint_dir")?.to_string()
                }
                other => bail!("unknown config key '{other}'"),
            }
        }
        if let Some(name) = straggler_name {
            cfg.straggler = StragglerPolicy::from_parts(&name, deadline_s, quorum_k)?;
        } else if deadline_s.is_some() || quorum_k.is_some() {
            bail!("deadline_s/quorum_k given without a 'straggler' policy");
        }
        cfg.sampling = ClientSampling::from_parts(sample_fraction, sample_k)?;
        cfg.codec_params.seed = cfg.seed;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Capacity of the shared uplink pipe: the explicit
    /// `shared_uplink_mbps` key, else the base link's uplink bandwidth.
    pub fn shared_capacity_bps(&self) -> f64 {
        self.shared_uplink_bps.unwrap_or(self.link.uplink_bps)
    }

    /// Capacity of the shared downlink (server-egress) pipe: the explicit
    /// `shared_downlink_mbps` key, else the base link's downlink
    /// bandwidth.
    pub fn shared_downlink_capacity_bps(&self) -> f64 {
        self.shared_downlink_bps.unwrap_or(self.link.downlink_bps)
    }

    /// Sanity-check ranges and key combinations. Every rejection names
    /// the offending key(s) and the value(s) that tripped it.
    pub fn validate(&self) -> Result<()> {
        if self.devices == 0 {
            bail!("devices must be > 0, got 0");
        }
        if self.rounds == 0 {
            bail!("rounds must be > 0, got 0");
        }
        if self.batches_per_round == 0 {
            bail!("batches_per_round must be > 0, got 0");
        }
        if self.batch_size == 0 {
            bail!("batch_size must be > 0, got 0");
        }
        if !(self.codec_params.theta > 0.0 && self.codec_params.theta <= 1.0) {
            bail!("theta must be in (0, 1], got {}", self.codec_params.theta);
        }
        crate::quant::AllocationConfig {
            b_min: self.codec_params.b_min,
            b_max: self.codec_params.b_max,
        }
        .validate()
        .map_err(|e| anyhow::anyhow!(e))?;
        if !(0.0..=1.0).contains(&self.codec_params.drop_threshold) {
            bail!(
                "drop_threshold must be in [0, 1], got {}",
                self.codec_params.drop_threshold
            );
        }
        if !(self.codec_params.subspace_fraction > 0.0
            && self.codec_params.subspace_fraction <= 1.0)
        {
            bail!(
                "subspace_fraction must be in (0, 1], got {}",
                self.codec_params.subspace_fraction
            );
        }
        if self.train_samples < self.devices {
            bail!(
                "train_samples = {} is smaller than devices = {} — every device needs data",
                self.train_samples,
                self.devices
            );
        }
        if self.lr <= 0.0 || self.lr > 10.0 {
            bail!("lr must be in (0, 10], got {}", self.lr);
        }
        if self.scheduler == SchedulerKind::Async && self.sync == SyncMode::Sequential {
            bail!(
                "scheduler = \"async\" requires sync = \"parallel\" (SplitFed), got \
                 sync = \"sequential\" — sequential SL is inherently serial"
            );
        }
        if self.scheduler == SchedulerKind::Sync && self.straggler != StragglerPolicy::WaitAll {
            bail!(
                "straggler = \"{}\" requires scheduler = \"async\", got \
                 scheduler = \"sync\" (lockstep rounds are inherently wait-all)",
                self.straggler.name()
            );
        }
        self.straggler.validate(self.devices)?;
        if !(self.base_compute_s.is_finite() && self.base_compute_s >= 0.0) {
            bail!("base_compute_s must be finite and >= 0, got {}", self.base_compute_s);
        }
        if !(self.server_service_s.is_finite() && self.server_service_s >= 0.0) {
            bail!(
                "server_service_s must be finite and >= 0, got {}",
                self.server_service_s
            );
        }
        match self.uplink {
            UplinkMode::Private => {
                if let Some(bps) = self.shared_uplink_bps {
                    bail!(
                        "shared_uplink_mbps = {} requires uplink = \"shared\", got \
                         uplink = \"private\"",
                        bps / 1e6
                    );
                }
            }
            UplinkMode::Shared => {
                let cap = self.shared_capacity_bps();
                if !(cap.is_finite() && cap > 0.0) {
                    // name the key the capacity actually came from
                    match self.shared_uplink_bps {
                        Some(_) => bail!(
                            "uplink = \"shared\" needs a positive finite capacity, \
                             got shared_uplink_mbps = {}",
                            cap / 1e6
                        ),
                        None => bail!(
                            "uplink = \"shared\" needs a positive finite capacity, \
                             got uplink_mbps = {} (shared_uplink_mbps is unset, so \
                             the capacity inherits uplink_mbps)",
                            cap / 1e6
                        ),
                    }
                }
                if self.link.jitter > 0.0 {
                    bail!(
                        "uplink = \"shared\" does not compose with link jitter \
                         (jitter = {}) — the fair-share pipe is jitter-free",
                        self.link.jitter
                    );
                }
                if self.sync == SyncMode::Sequential {
                    bail!(
                        "uplink = \"shared\" requires sync = \"parallel\", got \
                         sync = \"sequential\" — serial hand-off never contends \
                         for the pipe"
                    );
                }
            }
        }
        match self.downlink {
            DownlinkMode::Private => {
                if let Some(bps) = self.shared_downlink_bps {
                    bail!(
                        "shared_downlink_mbps = {} requires downlink = \"shared\", got \
                         downlink = \"private\"",
                        bps / 1e6
                    );
                }
            }
            DownlinkMode::Shared => {
                let cap = self.shared_downlink_capacity_bps();
                if !(cap.is_finite() && cap > 0.0) {
                    // name the key the capacity actually came from
                    match self.shared_downlink_bps {
                        Some(_) => bail!(
                            "downlink = \"shared\" needs a positive finite capacity, \
                             got shared_downlink_mbps = {}",
                            cap / 1e6
                        ),
                        None => bail!(
                            "downlink = \"shared\" needs a positive finite capacity, \
                             got downlink_mbps = {} (shared_downlink_mbps is unset, \
                             so the capacity inherits downlink_mbps)",
                            cap / 1e6
                        ),
                    }
                }
                if self.link.jitter > 0.0 {
                    bail!(
                        "downlink = \"shared\" does not compose with link jitter \
                         (jitter = {}) — the fair-share pipe is jitter-free",
                        self.link.jitter
                    );
                }
                if self.sync == SyncMode::Sequential {
                    bail!(
                        "downlink = \"shared\" requires sync = \"parallel\", got \
                         sync = \"sequential\" — serial hand-off never contends \
                         for the pipe"
                    );
                }
            }
        }
        if self.cohorts > self.devices {
            bail!(
                "cohorts = {} exceeds devices = {} — a cohort cannot be \
                 emptier than one device",
                self.cohorts,
                self.devices
            );
        }
        self.sampling.validate(self.devices)?;
        if let StragglerPolicy::Quorum { k } = self.straggler {
            // straggler.validate already bounded k by the fleet size; only
            // sampling can shrink the per-round participant count below it
            let sampled_value = match self.sampling {
                ClientSampling::Full => None,
                ClientSampling::Fraction(f) => Some(f.to_string()),
                ClientSampling::Count(c) => Some(c.to_string()),
            };
            if let Some(value) = sampled_value {
                let participants = self.sampling.effective_k(self.devices);
                if k > participants {
                    bail!(
                        "quorum_k = {k} exceeds the {participants} devices that \
                         {} = {value} samples per round — the quorum could never \
                         be reached",
                        self.sampling.name(),
                    );
                }
            }
        }
        self.fault.validate()?;
        if self.fault.is_active() {
            if self.sync == SyncMode::Sequential {
                bail!(
                    "fault injection (loss_prob/corrupt_prob/crash_rate/\
                     server_outage_s) requires sync = \"parallel\", got \
                     sync = \"sequential\" — the serial hand-off has no \
                     retry/drop semantics"
                );
            }
            if self.uplink == UplinkMode::Shared {
                bail!(
                    "fault injection does not compose with uplink = \"shared\" \
                     — retransmissions assume private per-device pipes"
                );
            }
            if self.downlink == DownlinkMode::Shared {
                bail!(
                    "fault injection does not compose with downlink = \"shared\" \
                     — retransmissions assume private per-device pipes"
                );
            }
        }
        // checkpointing: both-or-neither — a cadence without a directory
        // has nowhere to write, a directory without a cadence never writes
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_empty() {
            bail!(
                "checkpoint_every = {} requires checkpoint_dir (got an empty string)",
                self.checkpoint_every
            );
        }
        if self.checkpoint_every == 0 && !self.checkpoint_dir.is_empty() {
            bail!(
                "checkpoint_dir = \"{}\" requires checkpoint_every > 0, got 0",
                self.checkpoint_dir
            );
        }
        // profile spec must parse and assign cleanly at this device count
        crate::transport::assign_profiles(&self.profile, self.devices, self.link)?;
        Ok(())
    }

    /// Serialize (for embedding into result files).
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("dataset".into(), Json::Str(self.dataset.name().into()));
        m.insert("train_samples".into(), Json::Num(self.train_samples as f64));
        m.insert("test_samples".into(), Json::Num(self.test_samples as f64));
        m.insert("noise".into(), Json::Num(self.noise as f64));
        m.insert("devices".into(), Json::Num(self.devices as f64));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        match self.partition {
            Partition::Iid => {
                m.insert("partition".into(), Json::Str("iid".into()));
            }
            Partition::Dirichlet(beta) => {
                m.insert("partition".into(), Json::Str("dirichlet".into()));
                m.insert("dirichlet_beta".into(), Json::Num(beta));
            }
        }
        m.insert(
            "sync".into(),
            Json::Str(
                match self.sync {
                    SyncMode::ParallelFedAvg => "parallel",
                    SyncMode::Sequential => "sequential",
                }
                .into(),
            ),
        );
        m.insert("codec".into(), Json::Str(self.codec.clone()));
        m.insert("theta".into(), Json::Num(self.codec_params.theta));
        m.insert("b_min".into(), Json::Num(self.codec_params.b_min as f64));
        m.insert("b_max".into(), Json::Num(self.codec_params.b_max as f64));
        m.insert(
            "uniform_bits".into(),
            Json::Num(self.codec_params.uniform_bits as f64),
        );
        m.insert(
            "keep_fraction".into(),
            Json::Num(self.codec_params.keep_fraction),
        );
        m.insert(
            "random_fraction".into(),
            Json::Num(self.codec_params.random_fraction),
        );
        m.insert(
            "drop_threshold".into(),
            Json::Num(self.codec_params.drop_threshold),
        );
        m.insert(
            "subspace_fraction".into(),
            Json::Num(self.codec_params.subspace_fraction),
        );
        m.insert(
            "codec_fast_path".into(),
            Json::Bool(self.codec_params.fast_path),
        );
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert(
            "batches_per_round".into(),
            Json::Num(self.batches_per_round as f64),
        );
        m.insert("batch_size".into(), Json::Num(self.batch_size as f64));
        m.insert("lr".into(), Json::Num(self.lr as f64));
        m.insert("momentum".into(), Json::Num(self.momentum as f64));
        m.insert("scheduler".into(), Json::Str(self.scheduler.name().into()));
        m.insert("profile".into(), Json::Str(self.profile.clone()));
        m.insert("straggler".into(), Json::Str(self.straggler.name().into()));
        match self.straggler {
            StragglerPolicy::WaitAll => {}
            StragglerPolicy::DeadlineDrop { deadline_s } => {
                m.insert("deadline_s".into(), Json::Num(deadline_s));
            }
            StragglerPolicy::Quorum { k } => {
                m.insert("quorum_k".into(), Json::Num(k as f64));
            }
        }
        m.insert("uplink".into(), Json::Str(self.uplink.name().into()));
        if let Some(bps) = self.shared_uplink_bps {
            m.insert("shared_uplink_mbps".into(), Json::Num(bps / 1e6));
        }
        m.insert("downlink".into(), Json::Str(self.downlink.name().into()));
        if let Some(bps) = self.shared_downlink_bps {
            m.insert("shared_downlink_mbps".into(), Json::Num(bps / 1e6));
        }
        if self.cohorts > 0 {
            m.insert("cohorts".into(), Json::Num(self.cohorts as f64));
        }
        m.insert(
            "server_service_s".into(),
            Json::Num(self.server_service_s),
        );
        match self.sampling {
            ClientSampling::Full => {}
            ClientSampling::Fraction(f) => {
                m.insert("sample_fraction".into(), Json::Num(f));
            }
            ClientSampling::Count(k) => {
                m.insert("sample_k".into(), Json::Num(k as f64));
            }
        }
        // fault knobs: each key only when it differs from the default, so
        // fault-free configs keep their historical serialization bytes
        // (and thus fingerprints and sweep journal entries)
        let fd = FaultConfig::default();
        if self.fault.loss_prob != fd.loss_prob {
            m.insert("loss_prob".into(), Json::Num(self.fault.loss_prob));
        }
        if self.fault.corrupt_prob != fd.corrupt_prob {
            m.insert("corrupt_prob".into(), Json::Num(self.fault.corrupt_prob));
        }
        if self.fault.crash_rate != fd.crash_rate {
            m.insert("crash_rate".into(), Json::Num(self.fault.crash_rate));
        }
        if self.fault.max_retries != fd.max_retries {
            m.insert("max_retries".into(), Json::Num(self.fault.max_retries as f64));
        }
        if self.fault.retry_base_s != fd.retry_base_s {
            m.insert("retry_base_s".into(), Json::Num(self.fault.retry_base_s));
        }
        if self.fault.server_outage_s != fd.server_outage_s {
            m.insert(
                "server_outage_s".into(),
                Json::Num(self.fault.server_outage_s),
            );
        }
        m.insert("base_compute_s".into(), Json::Num(self.base_compute_s));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert(
            "compress_gradients".into(),
            Json::Bool(self.compress_gradients),
        );
        m.insert(
            "compute_fast_path".into(),
            Json::Bool(self.compute_fast_path),
        );
        Json::Obj(m)
    }

    /// Stable 64-bit fingerprint of the canonical serialization
    /// ([`ExperimentConfig::to_json`]). The sweep journal records it per
    /// run so a resumed sweep can detect that a journaled run no longer
    /// matches what the spec expands to. `artifacts_dir`,
    /// `checkpoint_every`, and `checkpoint_dir` are not part of `to_json`,
    /// so relocating artifacts or toggling checkpointing does not
    /// invalidate a journal (or a checkpoint's pinned fingerprint).
    pub fn fingerprint(&self) -> u64 {
        self.to_json().fingerprint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_keeps_fields() {
        let mut cfg = ExperimentConfig::default();
        cfg.codec = "tk-sl".into();
        cfg.rounds = 30;
        cfg.workers = 6;
        cfg.partition = Partition::Dirichlet(0.5);
        let json = cfg.to_json();
        let back = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(back.codec, "tk-sl");
        assert_eq!(back.rounds, 30);
        assert_eq!(back.workers, 6);
        assert_eq!(back.partition, Partition::Dirichlet(0.5));
    }

    #[test]
    fn workers_key_parses() {
        let json = Json::parse(r#"{"workers": 4}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&json).unwrap().workers, 4);
        // 0 = auto is accepted
        let json = Json::parse(r#"{"workers": 0}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&json).unwrap().workers, 0);
    }

    #[test]
    fn unknown_key_rejected() {
        let json = Json::parse(r#"{"codek": "slfac"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&json).is_err());
    }

    #[test]
    fn bad_values_rejected() {
        for bad in [
            r#"{"devices": 0}"#,
            r#"{"theta": 1.5}"#,
            r#"{"b_min": 9, "b_max": 8}"#,
            r#"{"partition": "weird"}"#,
            r#"{"lr": -1}"#,
        ] {
            let json = Json::parse(bad).unwrap();
            assert!(
                ExperimentConfig::from_json(&json).is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn codec_fast_path_parses_and_roundtrips() {
        // default true
        assert!(ExperimentConfig::default().codec_params.fast_path);
        let json = Json::parse(r#"{"codec_fast_path": false}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert!(!cfg.codec_params.fast_path);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert!(!back.codec_params.fast_path);
        // named-key validation: non-bool value is rejected with the key name
        let bad = Json::parse(r#"{"codec_fast_path": "yes"}"#).unwrap();
        let err = format!("{:#}", ExperimentConfig::from_json(&bad).unwrap_err());
        assert!(err.contains("codec_fast_path"), "{err}");
    }

    #[test]
    fn compute_fast_path_parses_and_roundtrips() {
        // default true
        assert!(ExperimentConfig::default().compute_fast_path);
        let json = Json::parse(r#"{"compute_fast_path": false}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert!(!cfg.compute_fast_path);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert!(!back.compute_fast_path);
        // non-bool value rejected with the key name
        let bad = Json::parse(r#"{"compute_fast_path": 1}"#).unwrap();
        let err = format!("{:#}", ExperimentConfig::from_json(&bad).unwrap_err());
        assert!(err.contains("compute_fast_path"), "{err}");
    }

    #[test]
    fn checkpoint_keys_parse_validate_and_stay_unserialized() {
        // defaults: checkpointing off
        let base = ExperimentConfig::default();
        assert_eq!(base.checkpoint_every, 0);
        assert!(base.checkpoint_dir.is_empty());
        // both keys together parse and validate
        let json =
            Json::parse(r#"{"checkpoint_every": 2, "checkpoint_dir": "ckpt"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.checkpoint_every, 2);
        assert_eq!(cfg.checkpoint_dir, "ckpt");
        // operational knobs: neither is serialized, so the fingerprint is
        // identical to the checkpoint-free config (journal/fingerprint
        // invariance — same rule as artifacts_dir)
        assert_eq!(cfg.fingerprint(), base.fingerprint());
        assert!(cfg.to_json().get("checkpoint_every").is_none());
        assert!(cfg.to_json().get("checkpoint_dir").is_none());
        // both-or-neither cross-validation, with named keys in the errors
        let bad = Json::parse(r#"{"checkpoint_every": 2}"#).unwrap();
        let err = format!("{:#}", ExperimentConfig::from_json(&bad).unwrap_err());
        assert!(err.contains("checkpoint_every = 2"), "{err}");
        assert!(err.contains("checkpoint_dir"), "{err}");
        let bad = Json::parse(r#"{"checkpoint_dir": "ckpt"}"#).unwrap();
        let err = format!("{:#}", ExperimentConfig::from_json(&bad).unwrap_err());
        assert!(err.contains("checkpoint_dir"), "{err}");
        assert!(err.contains("checkpoint_every"), "{err}");
        // named-key type errors
        let bad = Json::parse(r#"{"checkpoint_every": "two"}"#).unwrap();
        let err = format!("{:#}", ExperimentConfig::from_json(&bad).unwrap_err());
        assert!(err.contains("checkpoint_every"), "{err}");
    }

    #[test]
    fn random_fraction_roundtrips() {
        let json = Json::parse(r#"{"codec": "tk-sl", "random_fraction": 0.02}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert!((cfg.codec_params.random_fraction - 0.02).abs() < 1e-12);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(
            back.codec_params.random_fraction.to_bits(),
            cfg.codec_params.random_fraction.to_bits()
        );
    }

    #[test]
    fn fingerprint_tracks_every_serialized_knob() {
        let base = ExperimentConfig::default();
        assert_eq!(base.fingerprint(), ExperimentConfig::default().fingerprint());
        let mut c = base.clone();
        c.codec = "tk-sl".into();
        assert_ne!(base.fingerprint(), c.fingerprint());
        let mut c = base.clone();
        c.codec_params.theta = 0.5;
        assert_ne!(base.fingerprint(), c.fingerprint());
        // random_fraction is serialized (the tk-sl calibration depends on
        // it), so it must move the fingerprint too
        let mut c = base.clone();
        c.codec_params.random_fraction = 0.02;
        assert_ne!(base.fingerprint(), c.fingerprint());
        let mut c = base.clone();
        c.codec_params.drop_threshold = 0.4;
        assert_ne!(base.fingerprint(), c.fingerprint());
        let mut c = base.clone();
        c.codec_params.subspace_fraction = 0.25;
        assert_ne!(base.fingerprint(), c.fingerprint());
        let mut c = base.clone();
        c.seed = 99;
        assert_ne!(base.fingerprint(), c.fingerprint());
    }

    #[test]
    fn cluster_codec_keys_parse_and_roundtrip() {
        // defaults
        let base = ExperimentConfig::default();
        assert!((base.codec_params.drop_threshold - 0.2).abs() < 1e-12);
        assert!((base.codec_params.subspace_fraction - 0.5).abs() < 1e-12);
        let json = Json::parse(
            r#"{"codec": "nsc-sl", "drop_threshold": 0.35, "subspace_fraction": 0.125}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert!((cfg.codec_params.drop_threshold - 0.35).abs() < 1e-12);
        assert!((cfg.codec_params.subspace_fraction - 0.125).abs() < 1e-12);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(
            back.codec_params.drop_threshold.to_bits(),
            cfg.codec_params.drop_threshold.to_bits()
        );
        assert_eq!(
            back.codec_params.subspace_fraction.to_bits(),
            cfg.codec_params.subspace_fraction.to_bits()
        );
        // boundary values are legal: threshold 0 (keep all) and 1
        for ok in [
            r#"{"drop_threshold": 0.0}"#,
            r#"{"drop_threshold": 1.0}"#,
            r#"{"subspace_fraction": 1.0}"#,
        ] {
            let json = Json::parse(ok).unwrap();
            assert!(ExperimentConfig::from_json(&json).is_ok(), "{ok}");
        }
    }

    #[test]
    fn partition_aliases() {
        let json = Json::parse(r#"{"partition": "non-iid"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.partition, Partition::Dirichlet(0.5));
    }

    #[test]
    fn transport_keys_parse_and_roundtrip() {
        let json = Json::parse(
            r#"{"scheduler": "async", "profile": "wifi/lte",
                "straggler": "deadline-drop", "deadline_s": 0.75,
                "base_compute_s": 0.004}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.scheduler, SchedulerKind::Async);
        assert_eq!(cfg.profile, "wifi/lte");
        assert_eq!(cfg.straggler, StragglerPolicy::DeadlineDrop { deadline_s: 0.75 });
        assert!((cfg.base_compute_s - 0.004).abs() < 1e-12);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.scheduler, cfg.scheduler);
        assert_eq!(back.profile, cfg.profile);
        assert_eq!(back.straggler, cfg.straggler);

        let json = Json::parse(r#"{"scheduler": "async", "straggler": "quorum", "quorum_k": 3}"#)
            .unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.straggler, StragglerPolicy::Quorum { k: 3 });
    }

    #[test]
    fn transport_misconfigurations_rejected() {
        for bad in [
            // straggler policy on the sync scheduler
            r#"{"straggler": "quorum", "quorum_k": 2}"#,
            // async cannot drive sequential SL
            r#"{"scheduler": "async", "sync": "sequential"}"#,
            // deadline-drop without a deadline
            r#"{"scheduler": "async", "straggler": "deadline-drop"}"#,
            // quorum larger than the fleet (default 5 devices)
            r#"{"scheduler": "async", "straggler": "quorum", "quorum_k": 6}"#,
            // policy parameter without a policy
            r#"{"deadline_s": 1.0}"#,
            // unknown link class in the profile mix
            r#"{"profile": "wifi/adsl"}"#,
            r#"{"base_compute_s": -1.0}"#,
        ] {
            let json = Json::parse(bad).unwrap();
            assert!(
                ExperimentConfig::from_json(&json).is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn contention_keys_parse_and_roundtrip() {
        let json = Json::parse(
            r#"{"uplink": "shared", "shared_uplink_mbps": 40,
                "server_service_s": 0.003, "sample_fraction": 0.5}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.uplink, UplinkMode::Shared);
        assert!((cfg.shared_capacity_bps() - 40e6).abs() < 1.0);
        assert!((cfg.server_service_s - 0.003).abs() < 1e-12);
        assert_eq!(cfg.sampling, ClientSampling::Fraction(0.5));
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.uplink, cfg.uplink);
        assert_eq!(back.shared_uplink_bps, cfg.shared_uplink_bps);
        assert_eq!(back.server_service_s, cfg.server_service_s);
        assert_eq!(back.sampling, cfg.sampling);

        // shared capacity inherits uplink_mbps when not given
        let json = Json::parse(r#"{"uplink": "shared", "uplink_mbps": 25}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.shared_uplink_bps, None);
        assert!((cfg.shared_capacity_bps() - 25e6).abs() < 1.0);

        let json = Json::parse(r#"{"sample_k": 3}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.sampling, ClientSampling::Count(3));
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.sampling, cfg.sampling);
    }

    #[test]
    fn fleet_keys_parse_and_roundtrip() {
        let json = Json::parse(
            r#"{"downlink": "shared", "shared_downlink_mbps": 20, "cohorts": 4,
                "devices": 8}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.downlink, DownlinkMode::Shared);
        assert!((cfg.shared_downlink_capacity_bps() - 20e6).abs() < 1.0);
        assert_eq!(cfg.cohorts, 4);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.downlink, cfg.downlink);
        assert_eq!(back.shared_downlink_bps, cfg.shared_downlink_bps);
        assert_eq!(back.cohorts, cfg.cohorts);

        // shared downlink capacity inherits downlink_mbps when not given
        let json = Json::parse(r#"{"downlink": "shared", "downlink_mbps": 30}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(cfg.shared_downlink_bps, None);
        assert!((cfg.shared_downlink_capacity_bps() - 30e6).abs() < 1.0);

        // cohorts = 0 (the default) stays off the serialized form
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.cohorts, 0);
        assert!(!cfg.to_json().to_string().contains("cohorts"));
    }

    #[test]
    fn fleet_misconfigurations_rejected() {
        for bad in [
            // shared downlink capacity without shared mode
            r#"{"shared_downlink_mbps": 20}"#,
            // shared pipe is jitter-free
            r#"{"downlink": "shared", "jitter": 0.1}"#,
            // sequential SL never contends
            r#"{"downlink": "shared", "sync": "sequential"}"#,
            // zero capacity (explicit and inherited)
            r#"{"downlink": "shared", "shared_downlink_mbps": 0}"#,
            r#"{"downlink": "shared", "downlink_mbps": 0}"#,
            // unknown mode
            r#"{"downlink": "multicast"}"#,
            // more cohorts than devices (default 5)
            r#"{"cohorts": 6}"#,
        ] {
            let json = Json::parse(bad).unwrap();
            assert!(
                ExperimentConfig::from_json(&json).is_err(),
                "should reject {bad}"
            );
        }
        // cohorts composes with shared pipes (falls back to the per-device
        // scheduler paths) — allowed, not an error
        let json =
            Json::parse(r#"{"uplink": "shared", "jitter": 0.0, "cohorts": 2}"#).unwrap();
        assert!(ExperimentConfig::from_json(&json).is_ok());
    }

    #[test]
    fn contention_misconfigurations_rejected() {
        for bad in [
            // shared capacity without shared mode
            r#"{"shared_uplink_mbps": 40}"#,
            // shared pipe is jitter-free
            r#"{"uplink": "shared", "jitter": 0.1}"#,
            // sequential SL never contends
            r#"{"uplink": "shared", "sync": "sequential"}"#,
            // zero capacity
            r#"{"uplink": "shared", "shared_uplink_mbps": 0}"#,
            // unknown mode
            r#"{"uplink": "token-ring"}"#,
            // service time must be finite and non-negative
            r#"{"server_service_s": -0.5}"#,
            // sample_fraction outside (0, 1]
            r#"{"sample_fraction": 0.0}"#,
            r#"{"sample_fraction": 1.5}"#,
            r#"{"sample_fraction": -0.25}"#,
            // sample_k = 0
            r#"{"sample_k": 0}"#,
            // two spellings of one knob
            r#"{"sample_fraction": 0.5, "sample_k": 2}"#,
            // quorum larger than the sampled participant count (5 devices
            // * 0.4 = 2 participants < quorum 3)
            r#"{"scheduler": "async", "straggler": "quorum", "quorum_k": 3,
                "sample_fraction": 0.4}"#,
        ] {
            let json = Json::parse(bad).unwrap();
            assert!(
                ExperimentConfig::from_json(&json).is_err(),
                "should reject {bad}"
            );
        }
        // sample_k >= devices is NOT an error: it degrades to full
        // participation
        let json = Json::parse(r#"{"sample_k": 64}"#).unwrap();
        assert!(ExperimentConfig::from_json(&json).is_ok());
    }

    #[test]
    fn fault_keys_parse_and_roundtrip() {
        let json = Json::parse(
            r#"{"loss_prob": 0.1, "corrupt_prob": 0.02, "crash_rate": 0.05,
                "max_retries": 5, "retry_base_s": 0.2, "server_outage_s": 1.5}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert!((cfg.fault.loss_prob - 0.1).abs() < 1e-12);
        assert!((cfg.fault.corrupt_prob - 0.02).abs() < 1e-12);
        assert!((cfg.fault.crash_rate - 0.05).abs() < 1e-12);
        assert_eq!(cfg.fault.max_retries, 5);
        assert!((cfg.fault.retry_base_s - 0.2).abs() < 1e-12);
        assert!((cfg.fault.server_outage_s - 1.5).abs() < 1e-12);
        assert!(cfg.fault.is_active());
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.fault, cfg.fault);

        // fault knobs at defaults stay off the serialized form entirely —
        // the json bytes (and fingerprint) of a fault-free config are the
        // historical ones
        let clean = ExperimentConfig::default();
        assert!(!clean.fault.is_active());
        let s = clean.to_json().to_string();
        for key in [
            "loss_prob",
            "corrupt_prob",
            "crash_rate",
            "max_retries",
            "retry_base_s",
            "server_outage_s",
        ] {
            assert!(!s.contains(key), "default config serialized {key}");
        }

        // every fault knob moves the fingerprint
        let base = ExperimentConfig::default();
        let mut c = base.clone();
        c.fault.loss_prob = 0.1;
        assert_ne!(base.fingerprint(), c.fingerprint());
        let mut c = base.clone();
        c.fault.max_retries = 7;
        assert_ne!(base.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fault_misconfigurations_rejected() {
        for bad in [
            // probabilities out of range
            r#"{"loss_prob": 1.5}"#,
            r#"{"corrupt_prob": -0.1}"#,
            r#"{"crash_rate": 1.0}"#,
            // retry knobs out of range
            r#"{"max_retries": 33}"#,
            r#"{"retry_base_s": -0.5}"#,
            r#"{"loss_prob": 0.1, "retry_base_s": 0.0}"#,
            r#"{"server_outage_s": -1}"#,
            // fault layer needs the parallel schedulers
            r#"{"loss_prob": 0.1, "sync": "sequential"}"#,
            // retransmissions assume private pipes
            r#"{"loss_prob": 0.1, "uplink": "shared"}"#,
            r#"{"corrupt_prob": 0.1, "downlink": "shared"}"#,
        ] {
            let json = Json::parse(bad).unwrap();
            assert!(
                ExperimentConfig::from_json(&json).is_err(),
                "should reject {bad}"
            );
        }
        // inert retry knobs compose with everything (the layer is off)
        let json = Json::parse(r#"{"max_retries": 5, "sync": "sequential"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&json).is_ok());
    }

    #[test]
    fn validation_errors_name_the_offending_key() {
        let cases = [
            (r#"{"rounds": 0}"#, "rounds"),
            (r#"{"batches_per_round": 0}"#, "batches_per_round"),
            (r#"{"theta": 1.5}"#, "theta"),
            (r#"{"drop_threshold": 1.5}"#, "drop_threshold"),
            (r#"{"subspace_fraction": 0.0}"#, "subspace_fraction"),
            (r#"{"lr": -1}"#, "lr"),
            (r#"{"scheduler": "async", "sync": "sequential"}"#, "scheduler"),
            (r#"{"straggler": "quorum", "quorum_k": 2}"#, "straggler"),
            (r#"{"sample_fraction": 1.5}"#, "sample_fraction"),
            (r#"{"uplink": "shared", "jitter": 0.2}"#, "jitter"),
            (r#"{"shared_uplink_mbps": 10}"#, "shared_uplink_mbps"),
            // a bad *inherited* capacity must blame the key the user set
            // (uplink_mbps), not the one they never wrote
            (r#"{"uplink": "shared", "uplink_mbps": 0}"#, "uplink_mbps"),
            (r#"{"server_service_s": -1}"#, "server_service_s"),
            (r#"{"train_samples": 3, "devices": 5}"#, "train_samples"),
            (r#"{"shared_downlink_mbps": 10}"#, "shared_downlink_mbps"),
            // a bad *inherited* downlink capacity must blame downlink_mbps
            (r#"{"downlink": "shared", "downlink_mbps": 0}"#, "downlink_mbps"),
            (r#"{"cohorts": 9, "devices": 5}"#, "cohorts"),
        ];
        for (bad, key) in cases {
            let json = Json::parse(bad).unwrap();
            let err = format!("{:#}", ExperimentConfig::from_json(&json).unwrap_err());
            assert!(
                err.contains(key),
                "error for {bad} should name '{key}', got: {err}"
            );
        }
    }

    #[test]
    fn shipped_configs_validate() {
        // every preset in configs/ must load and cross-validate cleanly
        let mut seen = 0;
        for entry in std::fs::read_dir("configs").expect("configs/ exists") {
            let p = entry.unwrap().path();
            if p.extension().is_some_and(|e| e == "json") {
                ExperimentConfig::load(p.to_str().unwrap())
                    .unwrap_or_else(|e| panic!("{}: {e:#}", p.display()));
                seen += 1;
            }
        }
        assert!(seen >= 3, "expected the shipped presets, found {seen}");
    }

    #[test]
    fn link_units_convert() {
        let json =
            Json::parse(r#"{"uplink_mbps": 50, "latency_ms": 20}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&json).unwrap();
        assert!((cfg.link.uplink_bps - 50e6).abs() < 1.0);
        assert!((cfg.link.latency_s - 0.02).abs() < 1e-9);
    }
}
