//! Orthonormal DCT-II / DCT-III (inverse) transforms.
//!
//! The paper's AFD (Eq. 1–2) applies a per-channel 2-D DCT-II with the
//! orthonormal scaling `α(u), β(v)`. On the wire path the transform is
//! produced *inside the HLO graph* by the Pallas kernel (L1); this Rust
//! implementation exists for
//!
//! 1. the standalone/pure-Rust codec mode (unit tests, benches, the sim
//!    executor backend, and tools that run without artifacts),
//! 2. golden-vector cross-validation against the Pallas kernel, and
//! 3. the L3 perf baseline the benches compare against.
//!
//! # Kernel selection (which path computes what)
//!
//! [`Dct2d::forward`] / [`Dct2d::inverse`] pick per plan:
//!
//! * **Fast path** — when *both* dimensions are powers of two (8×8, 16×16,
//!   32×32 CIFAR-scale planes, the sim backend's test shapes), a Lee
//!   recursive DCT-II/III runs in `O(N log N)` with precomputed twiddle
//!   tables from the shared [`DctPlan`]. All intermediates are f64, so the
//!   fast path is *more* accurate than the reference's f32 intermediate
//!   plane, but it is **not bit-identical** to it (different operation
//!   order). That is fine everywhere it runs: the codec wire-byte identity
//!   contract covers the codec kernels (which consume coefficient planes —
//!   the DCT sits in front of them), and every DCT consumer checks
//!   tolerances, not bits.
//! * **Planned matmul path** — all other sizes (e.g. MNIST's 14×14) run
//!   the basis-matrix form `DCT2(X) = D_M · X · D_Nᵀ` with the
//!   pre-transposed operand from the plan and an i-k-j loop order over f64
//!   row accumulators: unit-stride inner loops (vectorizable), and — since
//!   each output element still folds the same addends in ascending-k
//!   order — **bit-identical** to the historical i-j-k reference.
//! * **Reference path** — [`Dct2d::forward_ref`] / [`Dct2d::inverse_ref`]
//!   always run the f64-accumulating basis matmul regardless of size. They
//!   exist for golden cross-validation (Pallas goldens, the fast-vs-ref
//!   differential tests below); fidelity, not speed, is their job. Note
//!   they are selected **programmatically only** — the `codec_fast_path`
//!   config flag switches the SL-FAC *channel kernels*, not the transform:
//!   `Dct2d::forward`/`inverse` pick fast-vs-matmul purely by shape. The
//!   historical comment claiming "the hot codec path never calls this" was
//!   stale — in standalone mode the transform *is* on the hot path, which
//!   is exactly why the fast/planned paths above exist.
//!
//! Basis matrices, transposes, and twiddle tables are cached per size in a
//! lock-free [`crate::codec::plan::SnapshotCache`] (one atomic load per
//! lookup — the historical `Mutex<HashMap>` is gone). The inverse
//! (DCT-III) is `D_Mᵀ · Y · D_N` because `D` is orthogonal.

use crate::codec::plan::SnapshotCache;
use std::sync::{Arc, OnceLock};

/// An `MxM` orthonormal DCT-II basis matrix (row-major).
#[derive(Debug, Clone)]
pub struct DctBasis {
    /// Transform size.
    pub size: usize,
    /// Row-major `size*size` matrix; row `u` holds the `u`-th cosine basis.
    pub mat: Vec<f32>,
}

impl DctBasis {
    /// Build the orthonormal DCT-II matrix of the given size.
    pub fn build(size: usize) -> Self {
        assert!(size > 0);
        let m = size as f64;
        let mut mat = vec![0.0f32; size * size];
        for u in 0..size {
            let alpha = if u == 0 {
                (1.0 / m).sqrt()
            } else {
                (2.0 / m).sqrt()
            };
            for x in 0..size {
                let v = alpha
                    * ((std::f64::consts::PI / m) * (x as f64 + 0.5) * u as f64).cos();
                mat[u * size + x] = v as f32;
            }
        }
        DctBasis { size, mat }
    }
}

fn basis_cache() -> &'static SnapshotCache<usize, DctBasis> {
    static CACHE: OnceLock<SnapshotCache<usize, DctBasis>> = OnceLock::new();
    CACHE.get_or_init(SnapshotCache::new)
}

/// Fetch (building on first use) the cached basis of a given size.
/// Lock-free on the hot (cached) path.
pub fn basis(size: usize) -> Arc<DctBasis> {
    basis_cache().get_or_build(size, || DctBasis::build(size))
}

/// Twiddle tables for Lee's recursive DCT-II/III at one power-of-two size.
///
/// `factors` concatenates, for each recursion level `len = N, N/2, …, 2`,
/// the `len/2` values `1 / (2·cos((i+½)·π/len))`; the level for `len`
/// starts at offset `N − len`. `alpha` holds the orthonormal scale
/// `α(0) = √(1/N)`, `α(k) = √(2/N)`.
#[derive(Debug)]
pub struct FastDct {
    n: usize,
    factors: Vec<f64>,
    alpha: Vec<f64>,
}

impl FastDct {
    /// Build tables for a power-of-two `n`.
    fn build(n: usize) -> Self {
        assert!(n.is_power_of_two());
        let mut factors = Vec::with_capacity(n.saturating_sub(1));
        let mut len = n;
        while len >= 2 {
            for i in 0..len / 2 {
                let c = ((i as f64 + 0.5) * std::f64::consts::PI / len as f64).cos();
                factors.push(1.0 / (2.0 * c));
            }
            len /= 2;
        }
        let nf = n as f64;
        let mut alpha = vec![(2.0 / nf).sqrt(); n];
        alpha[0] = (1.0 / nf).sqrt();
        FastDct { n, factors, alpha }
    }

    /// Twiddle slice for recursion size `len` (`len/2` entries).
    #[inline]
    fn level(&self, len: usize) -> &[f64] {
        &self.factors[self.n - len..self.n - len / 2]
    }

    /// In-place unnormalized DCT-II (Lee):
    /// `v[k] ← Σ_i v[i]·cos(π/L·(i+½)·k)`. `temp` must be `v.len()` long.
    fn fwd(&self, v: &mut [f64], temp: &mut [f64]) {
        let len = v.len();
        if len == 1 {
            return;
        }
        let half = len / 2;
        let f = self.level(len);
        {
            let (a, b) = temp.split_at_mut(half);
            for i in 0..half {
                let x = v[i];
                let y = v[len - 1 - i];
                a[i] = x + y;
                b[i] = (x - y) * f[i];
            }
            let (va, vb) = v.split_at_mut(half);
            self.fwd(a, va);
            self.fwd(b, vb);
        }
        for i in 0..half - 1 {
            v[2 * i] = temp[i];
            v[2 * i + 1] = temp[half + i] + temp[half + i + 1];
        }
        v[len - 2] = temp[half - 1];
        v[len - 1] = temp[len - 1];
    }

    /// In-place unnormalized DCT-III (Lee inverse):
    /// `v[i] ← Σ_k v[k]·cos(π/L·(i+½)·k)` (full weight on `k = 0`).
    fn inv(&self, v: &mut [f64], temp: &mut [f64]) {
        let len = v.len();
        if len == 1 {
            return;
        }
        let half = len / 2;
        {
            let (a, b) = temp.split_at_mut(half);
            a[0] = v[0];
            b[0] = v[1];
            for i in 1..half {
                a[i] = v[2 * i];
                b[i] = v[2 * i - 1] + v[2 * i + 1];
            }
            let (va, vb) = v.split_at_mut(half);
            self.inv(a, va);
            self.inv(b, vb);
        }
        let f = self.level(len);
        for i in 0..half {
            let x = temp[i];
            let y = temp[half + i] * f[i];
            v[i] = x + y;
            v[len - 1 - i] = x - y;
        }
    }
}

/// Immutable per-`(M, N)` transform plan: basis matrices, pre-transposed
/// variants for the cache-friendly matmul, and fast power-of-two twiddles.
/// Shared via the lock-free plan cache ([`plan`]); [`Dct2d`] adds the
/// mutable scratch on top.
#[derive(Debug)]
pub struct DctPlan {
    /// Plane height.
    pub m: usize,
    /// Plane width.
    pub n: usize,
    /// Row basis `D_M`.
    pub dm: Arc<DctBasis>,
    /// Column basis `D_N`.
    pub dn: Arc<DctBasis>,
    /// `D_Mᵀ` (row-major `M×M`).
    dm_t: Vec<f32>,
    /// `D_Nᵀ` (row-major `N×N`).
    dn_t: Vec<f32>,
    /// Lee twiddles for the row dimension (power-of-two `M` only).
    fast_m: Option<FastDct>,
    /// Lee twiddles for the column dimension (power-of-two `N` only).
    fast_n: Option<FastDct>,
}

impl DctPlan {
    fn build(m: usize, n: usize) -> Self {
        let dm = basis(m);
        let dn = basis(n);
        let dm_t = transpose(&dm.mat, m, m);
        let dn_t = transpose(&dn.mat, n, n);
        let fast_m = m.is_power_of_two().then(|| FastDct::build(m));
        let fast_n = n.is_power_of_two().then(|| FastDct::build(n));
        DctPlan {
            m,
            n,
            dm,
            dn,
            dm_t,
            dn_t,
            fast_m,
            fast_n,
        }
    }

    /// Whether the `O(N log N)` Lee path covers this shape (both
    /// dimensions powers of two).
    pub fn has_fast_path(&self) -> bool {
        self.fast_m.is_some() && self.fast_n.is_some()
    }
}

fn dct_plan_cache() -> &'static SnapshotCache<(usize, usize), DctPlan> {
    static CACHE: OnceLock<SnapshotCache<(usize, usize), DctPlan>> = OnceLock::new();
    CACHE.get_or_init(SnapshotCache::new)
}

/// Fetch (building on first use) the transform plan for `M×N` planes.
/// Lock-free on the hot (cached) path.
pub fn plan(m: usize, n: usize) -> Arc<DctPlan> {
    dct_plan_cache().get_or_build((m, n), || DctPlan::build(m, n))
}

/// Reference matmul `out = A(M×K) · B(K×N)` (row-major, f64 accumulate,
/// i-j-k order). Kept verbatim for golden cross-validation — the planned
/// i-k-j kernel below is bit-identical to it by construction.
fn matmul_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            out[i * n + j] = acc as f32;
        }
    }
}

/// Cache-friendly matmul: i-k-j loop order with an f64 accumulator row —
/// the inner loop walks `b`'s row `p` and `acc` with unit stride
/// (vectorizable), while each `out[i][j]` still folds its addends in the
/// same ascending-`p` order as [`matmul_ref`], so the result is
/// **bit-identical** (f64 addition of the same sequence).
fn matmul_ikj(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    acc: &mut [f64],
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    assert!(acc.len() >= n);
    let acc = &mut acc[..n];
    for i in 0..m {
        acc.fill(0.0);
        for p in 0..k {
            let av = a[i * k + p] as f64;
            let brow = &b[p * n..(p + 1) * n];
            for (ac, &bv) in acc.iter_mut().zip(brow) {
                *ac += av * bv as f64;
            }
        }
        for (o, &ac) in out[i * n..(i + 1) * n].iter_mut().zip(acc.iter()) {
            *o = ac as f32;
        }
    }
}

fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; src.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

/// Scratch buffers + shared plan for repeated 2-D transforms of a fixed
/// `(M, N)` size. Reusing a `Dct2d` performs zero allocations per call.
#[derive(Debug)]
pub struct Dct2d {
    /// Spatial height.
    pub m: usize,
    /// Spatial width.
    pub n: usize,
    plan: Arc<DctPlan>,
    /// matmul intermediate (M×N, f32)
    tmp: Vec<f32>,
    /// f64 accumulator row for the i-k-j matmul
    acc: Vec<f64>,
    /// fast-path f64 plane
    fplane: Vec<f64>,
    /// fast-path column + recursion scratch (2·max(M, N))
    fvec: Vec<f64>,
}

impl Dct2d {
    /// Create a transformer for `M×N` planes (plan fetched from the cache).
    /// Scratch is sized for the path this shape actually takes: Lee-path
    /// shapes skip the matmul accumulator, matmul shapes skip the f64
    /// plane (`tmp` stays — the `_ref` paths need it either way).
    pub fn new(m: usize, n: usize) -> Self {
        let plan = plan(m, n);
        let dim = m.max(n);
        let fast = plan.has_fast_path();
        Dct2d {
            m,
            n,
            plan,
            tmp: vec![0.0f32; m * n],
            acc: if fast { Vec::new() } else { vec![0.0f64; dim] },
            fplane: if fast { vec![0.0f64; m * n] } else { Vec::new() },
            fvec: if fast { vec![0.0f64; 2 * dim] } else { Vec::new() },
        }
    }

    /// Whether this shape runs the Lee fast path.
    pub fn has_fast_path(&self) -> bool {
        self.plan.has_fast_path()
    }

    /// Forward 2-D DCT-II: `out = D_M · x · D_Nᵀ`. `x` and `out` are `M*N`.
    /// Fast Lee path for power-of-two shapes, planned matmul otherwise
    /// (bit-identical to [`Dct2d::forward_ref`] there).
    pub fn forward(&mut self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.m * self.n);
        assert_eq!(out.len(), self.m * self.n);
        if self.plan.has_fast_path() {
            self.fast_forward(x, out);
            return;
        }
        // tmp = D_M (M×M) · x (M×N); out = tmp (M×N) · D_Nᵀ (N×N)
        matmul_ikj(&self.plan.dm.mat, x, self.m, self.m, self.n, &mut self.acc, &mut self.tmp);
        matmul_ikj(&self.tmp, &self.plan.dn_t, self.m, self.n, self.n, &mut self.acc, out);
    }

    /// Inverse (DCT-III): `out = D_Mᵀ · y · D_N`. Fast Lee path for
    /// power-of-two shapes, planned matmul otherwise.
    pub fn inverse(&mut self, y: &[f32], out: &mut [f32]) {
        assert_eq!(y.len(), self.m * self.n);
        assert_eq!(out.len(), self.m * self.n);
        if self.plan.has_fast_path() {
            self.fast_inverse(y, out);
            return;
        }
        matmul_ikj(&self.plan.dm_t, y, self.m, self.m, self.n, &mut self.acc, &mut self.tmp);
        matmul_ikj(&self.tmp, &self.plan.dn.mat, self.m, self.n, self.n, &mut self.acc, out);
    }

    /// Reference forward: always the f64-accumulating basis matmul,
    /// regardless of shape. Exported for golden cross-validation and the
    /// `codec_fast_path = false` debug mode.
    pub fn forward_ref(&mut self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.m * self.n);
        assert_eq!(out.len(), self.m * self.n);
        matmul_ref(&self.plan.dm.mat, x, self.m, self.m, self.n, &mut self.tmp);
        matmul_ref(&self.tmp, &self.plan.dn_t, self.m, self.n, self.n, out);
    }

    /// Reference inverse (see [`Dct2d::forward_ref`]).
    pub fn inverse_ref(&mut self, y: &[f32], out: &mut [f32]) {
        assert_eq!(y.len(), self.m * self.n);
        assert_eq!(out.len(), self.m * self.n);
        matmul_ref(&self.plan.dm_t, y, self.m, self.m, self.n, &mut self.tmp);
        matmul_ref(&self.tmp, &self.plan.dn.mat, self.m, self.n, self.n, out);
    }

    fn fast_forward(&mut self, x: &[f32], out: &mut [f32]) {
        let (m, n) = (self.m, self.n);
        let fm = self.plan.fast_m.as_ref().expect("fast path");
        let fn_ = self.plan.fast_n.as_ref().expect("fast path");
        // rows (length n), scaled by α_n, all in f64
        for r in 0..m {
            let row = &mut self.fplane[r * n..(r + 1) * n];
            for (d, &s) in row.iter_mut().zip(&x[r * n..(r + 1) * n]) {
                *d = s as f64;
            }
            fn_.fwd(row, &mut self.fvec[..n]);
            for (d, &a) in row.iter_mut().zip(&fn_.alpha) {
                *d *= a;
            }
        }
        // columns (length m), scaled by α_m
        let (col, temp) = self.fvec.split_at_mut(m);
        for c in 0..n {
            for (r, cv) in col.iter_mut().enumerate() {
                *cv = self.fplane[r * n + c];
            }
            fm.fwd(col, &mut temp[..m]);
            for r in 0..m {
                out[r * n + c] = (col[r] * fm.alpha[r]) as f32;
            }
        }
    }

    fn fast_inverse(&mut self, y: &[f32], out: &mut [f32]) {
        let (m, n) = (self.m, self.n);
        let fm = self.plan.fast_m.as_ref().expect("fast path");
        let fn_ = self.plan.fast_n.as_ref().expect("fast path");
        // rows: pre-scale by α_n, inverse-transform
        for r in 0..m {
            let row = &mut self.fplane[r * n..(r + 1) * n];
            for ((d, &s), &a) in row.iter_mut().zip(&y[r * n..(r + 1) * n]).zip(&fn_.alpha) {
                *d = s as f64 * a;
            }
            fn_.inv(row, &mut self.fvec[..n]);
        }
        // columns: pre-scale by α_m, inverse-transform
        let (col, temp) = self.fvec.split_at_mut(m);
        for c in 0..n {
            for (r, cv) in col.iter_mut().enumerate() {
                *cv = self.fplane[r * n + c] * fm.alpha[r];
            }
            fm.inv(col, &mut temp[..m]);
            for r in 0..m {
                out[r * n + c] = col[r] as f32;
            }
        }
    }

    /// Convenience: forward transform of every channel of a (B,C,M,N) tensor,
    /// returning a tensor of identical shape holding coefficients.
    pub fn forward_tensor(x: &crate::tensor::Tensor) -> crate::tensor::Tensor {
        let (b, c, m, n) = x.as_bchw();
        let mut t = Dct2d::new(m, n);
        let mut out = crate::tensor::Tensor::zeros(x.shape());
        for bi in 0..b {
            for ci in 0..c {
                t.forward(x.channel(bi, ci), out.channel_mut(bi, ci));
            }
        }
        out
    }

    /// Convenience: inverse transform of every channel of a (B,C,M,N) tensor.
    pub fn inverse_tensor(y: &crate::tensor::Tensor) -> crate::tensor::Tensor {
        let (b, c, m, n) = y.as_bchw();
        let mut t = Dct2d::new(m, n);
        let mut out = crate::tensor::Tensor::zeros(y.shape());
        for bi in 0..b {
            for ci in 0..c {
                t.inverse(y.channel(bi, ci), out.channel_mut(bi, ci));
            }
        }
        out
    }
}

/// 1-D orthonormal DCT-II of a vector (reference/tests).
pub fn dct1d(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    let b = basis(n);
    let mut out = vec![0.0f32; n];
    for u in 0..n {
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += b.mat[u * n + i] as f64 * x[i] as f64;
        }
        out[u] = acc as f32;
    }
    out
}

/// 1-D inverse (DCT-III) of a vector (reference/tests).
pub fn idct1d(y: &[f32]) -> Vec<f32> {
    let n = y.len();
    let b = basis(n);
    let mut out = vec![0.0f32; n];
    for i in 0..n {
        let mut acc = 0.0f64;
        for u in 0..n {
            acc += b.mat[u * n + i] as f64 * y[u] as f64;
        }
        out[i] = acc as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::tensor::Tensor;

    #[test]
    fn basis_is_orthonormal() {
        for &n in &[1usize, 2, 4, 7, 14, 16] {
            let b = basis(n);
            // D · Dᵀ = I
            for r in 0..n {
                for c in 0..n {
                    let mut acc = 0.0f64;
                    for k in 0..n {
                        acc += b.mat[r * n + k] as f64 * b.mat[c * n + k] as f64;
                    }
                    let expect = if r == c { 1.0 } else { 0.0 };
                    assert!(
                        (acc - expect).abs() < 1e-5,
                        "n={n} ({r},{c}) got {acc}"
                    );
                }
            }
        }
    }

    #[test]
    fn dc_component_of_constant_signal() {
        // DCT-II of a constant c over n points: X[0] = c*sqrt(n), rest 0.
        let n = 8;
        let x = vec![3.0f32; n];
        let y = dct1d(&x);
        assert!((y[0] - 3.0 * (n as f32).sqrt()).abs() < 1e-4);
        for &v in &y[1..] {
            assert!(v.abs() < 1e-5);
        }
    }

    #[test]
    fn roundtrip_1d() {
        let mut rng = Pcg32::seeded(1);
        let x: Vec<f32> = (0..13).map(|_| rng.normal()).collect();
        let back = idct1d(&dct1d(&x));
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn roundtrip_2d() {
        let mut rng = Pcg32::seeded(2);
        let (m, n) = (14, 10);
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut t = Dct2d::new(m, n);
        let mut y = vec![0.0f32; m * n];
        let mut back = vec![0.0f32; m * n];
        t.forward(&x, &mut y);
        t.inverse(&y, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn roundtrip_2d_pow2_fast_path() {
        let mut rng = Pcg32::seeded(12);
        for &(m, n) in &[(2usize, 2usize), (4, 8), (8, 8), (16, 16), (32, 32), (1, 16)] {
            let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut t = Dct2d::new(m, n);
            assert!(t.has_fast_path(), "{m}x{n}");
            let mut y = vec![0.0f32; m * n];
            let mut back = vec![0.0f32; m * n];
            t.forward(&x, &mut y);
            t.inverse(&y, &mut back);
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() < 1e-4, "{m}x{n}");
            }
        }
    }

    #[test]
    fn fast_path_matches_reference_within_tolerance() {
        // The Lee path is a different operation order, not a different
        // transform: it must agree with the f64 basis matmul to f32
        // round-off levels.
        let mut rng = Pcg32::seeded(13);
        for &(m, n) in &[(4usize, 4usize), (8, 8), (16, 8), (32, 32)] {
            let x: Vec<f32> = (0..m * n).map(|_| rng.normal() * 3.0).collect();
            let mut t = Dct2d::new(m, n);
            let mut fast = vec![0.0f32; m * n];
            let mut reference = vec![0.0f32; m * n];
            t.forward(&x, &mut fast);
            t.forward_ref(&x, &mut reference);
            for (a, b) in fast.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "{m}x{n} fwd: {a} vs {b}");
            }
            let mut ifast = vec![0.0f32; m * n];
            let mut iref = vec![0.0f32; m * n];
            t.inverse(&fast, &mut ifast);
            t.inverse_ref(&reference, &mut iref);
            for (a, b) in ifast.iter().zip(&iref) {
                assert!((a - b).abs() < 1e-4, "{m}x{n} inv: {a} vs {b}");
            }
        }
    }

    #[test]
    fn planned_matmul_is_bit_identical_to_reference() {
        // Non-power-of-two shapes take the i-k-j matmul, which must be
        // bit-for-bit the historical i-j-k reference (same addends, same
        // fold order per output element) — this is what keeps the wire
        // golden vectors (6×6) and every 14×14 MNIST byte stream frozen.
        let mut rng = Pcg32::seeded(14);
        for &(m, n) in &[(6usize, 6usize), (14, 14), (14, 10), (7, 3), (5, 12)] {
            let x: Vec<f32> = (0..m * n).map(|_| rng.normal() * 2.0).collect();
            let mut t = Dct2d::new(m, n);
            assert!(!t.has_fast_path(), "{m}x{n}");
            let mut fwd = vec![0.0f32; m * n];
            let mut fwd_ref = vec![0.0f32; m * n];
            t.forward(&x, &mut fwd);
            t.forward_ref(&x, &mut fwd_ref);
            assert_eq!(fwd, fwd_ref, "{m}x{n} forward must be bit-identical");
            let mut inv = vec![0.0f32; m * n];
            let mut inv_ref = vec![0.0f32; m * n];
            t.inverse(&fwd, &mut inv);
            t.inverse_ref(&fwd, &mut inv_ref);
            assert_eq!(inv, inv_ref, "{m}x{n} inverse must be bit-identical");
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        // Orthonormal transform preserves sum of squares (fast path here:
        // 8×8 is a power of two).
        let mut rng = Pcg32::seeded(3);
        let (m, n) = (8, 8);
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut t = Dct2d::new(m, n);
        let mut y = vec![0.0f32; m * n];
        t.forward(&x, &mut y);
        let ex: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        let ey: f64 = y.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((ex - ey).abs() / ex < 1e-5);
    }

    #[test]
    fn tensor_roundtrip() {
        let mut rng = Pcg32::seeded(4);
        let x = Tensor::randn(&[2, 3, 6, 5], 1.0, &mut rng);
        let y = Dct2d::forward_tensor(&x);
        let back = Dct2d::inverse_tensor(&y);
        assert!(x.max_abs_diff(&back) < 1e-4);
    }

    #[test]
    fn smooth_signal_concentrates_low_freq() {
        // A smooth ramp should put most energy into low-index coefficients.
        let n = 16;
        let x: Vec<f32> = (0..n).map(|i| i as f32 / n as f32).collect();
        let y = dct1d(&x);
        let total: f64 = y.iter().map(|&v| (v as f64).powi(2)).sum();
        let low: f64 = y[..4].iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(low / total > 0.99, "low fraction {}", low / total);
    }

    #[test]
    fn plan_cache_shares_instances() {
        let a = plan(14, 14);
        let b = plan(14, 14);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(plan(8, 8).has_fast_path());
        assert!(!plan(14, 14).has_fast_path());
    }
}
