//! Orthonormal DCT-II / DCT-III (inverse) transforms.
//!
//! The paper's AFD (Eq. 1–2) applies a per-channel 2-D DCT-II with the
//! orthonormal scaling `α(u), β(v)`. On the wire path the transform is
//! produced *inside the HLO graph* by the Pallas kernel (L1); this Rust
//! implementation exists for
//!
//! 1. the standalone/pure-Rust codec mode (unit tests, benches, and tools
//!    that run without artifacts),
//! 2. golden-vector cross-validation against the Pallas kernel, and
//! 3. the L3 perf baseline the benches compare against.
//!
//! Implementation: basis-matrix form. `DCT2(X) = D_M · X · D_Nᵀ` with
//! `D_M[u,m] = α(u)·cos(π/M·(m+½)·u)` (0-based), which is exactly Eq. 1.
//! Basis matrices are cached per size. The inverse (DCT-III) is `D_Mᵀ · Y · D_N`
//! because `D` is orthogonal.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::sync::Arc;

/// An `MxM` orthonormal DCT-II basis matrix (row-major).
#[derive(Debug, Clone)]
pub struct DctBasis {
    /// Transform size.
    pub size: usize,
    /// Row-major `size*size` matrix; row `u` holds the `u`-th cosine basis.
    pub mat: Vec<f32>,
}

impl DctBasis {
    /// Build the orthonormal DCT-II matrix of the given size.
    pub fn build(size: usize) -> Self {
        assert!(size > 0);
        let m = size as f64;
        let mut mat = vec![0.0f32; size * size];
        for u in 0..size {
            let alpha = if u == 0 {
                (1.0 / m).sqrt()
            } else {
                (2.0 / m).sqrt()
            };
            for x in 0..size {
                let v = alpha
                    * ((std::f64::consts::PI / m) * (x as f64 + 0.5) * u as f64).cos();
                mat[u * size + x] = v as f32;
            }
        }
        DctBasis { size, mat }
    }
}

fn basis_cache() -> &'static Mutex<HashMap<usize, Arc<DctBasis>>> {
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<DctBasis>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Fetch (building on first use) the cached basis of a given size.
pub fn basis(size: usize) -> Arc<DctBasis> {
    let mut cache = basis_cache().lock().unwrap();
    cache
        .entry(size)
        .or_insert_with(|| Arc::new(DctBasis::build(size)))
        .clone()
}

/// `out = A(M×K) · B(K×N)` into a caller-provided buffer (row-major, f32
/// accumulate in f64 for the small sizes used here — fidelity matters more
/// than speed on this path; the hot codec path never calls this).
fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            out[i * n + j] = acc as f32;
        }
    }
}

/// Scratch buffers for repeated 2-D transforms of a fixed (M, N) size.
///
/// Reusing a `Dct2d` avoids per-call allocation on bench/codec loops.
#[derive(Debug)]
pub struct Dct2d {
    /// Spatial height.
    pub m: usize,
    /// Spatial width.
    pub n: usize,
    dm: Arc<DctBasis>,
    dn: Arc<DctBasis>,
    /// transposed D_N (N×N) for the row-transform step
    dn_t: Vec<f32>,
    /// transposed D_M
    dm_t: Vec<f32>,
    tmp: Vec<f32>,
}

fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; src.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

impl Dct2d {
    /// Create a transformer for `M×N` planes.
    pub fn new(m: usize, n: usize) -> Self {
        let dm = basis(m);
        let dn = basis(n);
        let dn_t = transpose(&dn.mat, n, n);
        let dm_t = transpose(&dm.mat, m, m);
        Dct2d {
            m,
            n,
            dm,
            dn,
            dn_t,
            dm_t,
            tmp: vec![0.0f32; m * n],
        }
    }

    /// Forward 2-D DCT-II: `out = D_M · x · D_Nᵀ`. `x` and `out` are `M*N`.
    pub fn forward(&mut self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.m * self.n);
        assert_eq!(out.len(), self.m * self.n);
        // tmp = D_M (M×M) · x (M×N)
        matmul_into(&self.dm.mat, x, self.m, self.m, self.n, &mut self.tmp);
        // out = tmp (M×N) · D_Nᵀ (N×N)
        matmul_into(&self.tmp, &self.dn_t, self.m, self.n, self.n, out);
    }

    /// Inverse (DCT-III): `out = D_Mᵀ · y · D_N`.
    pub fn inverse(&mut self, y: &[f32], out: &mut [f32]) {
        assert_eq!(y.len(), self.m * self.n);
        assert_eq!(out.len(), self.m * self.n);
        matmul_into(&self.dm_t, y, self.m, self.m, self.n, &mut self.tmp);
        matmul_into(&self.tmp, &self.dn.mat, self.m, self.n, self.n, out);
    }

    /// Convenience: forward transform of every channel of a (B,C,M,N) tensor,
    /// returning a tensor of identical shape holding coefficients.
    pub fn forward_tensor(x: &crate::tensor::Tensor) -> crate::tensor::Tensor {
        let (b, c, m, n) = x.as_bchw();
        let mut t = Dct2d::new(m, n);
        let mut out = crate::tensor::Tensor::zeros(x.shape());
        for bi in 0..b {
            for ci in 0..c {
                let src = x.channel(bi, ci).to_vec();
                t.forward(&src, out.channel_mut(bi, ci));
            }
        }
        out
    }

    /// Convenience: inverse transform of every channel of a (B,C,M,N) tensor.
    pub fn inverse_tensor(y: &crate::tensor::Tensor) -> crate::tensor::Tensor {
        let (b, c, m, n) = y.as_bchw();
        let mut t = Dct2d::new(m, n);
        let mut out = crate::tensor::Tensor::zeros(y.shape());
        for bi in 0..b {
            for ci in 0..c {
                let src = y.channel(bi, ci).to_vec();
                t.inverse(&src, out.channel_mut(bi, ci));
            }
        }
        out
    }
}

/// 1-D orthonormal DCT-II of a vector (reference/tests).
pub fn dct1d(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    let b = basis(n);
    let mut out = vec![0.0f32; n];
    for u in 0..n {
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += b.mat[u * n + i] as f64 * x[i] as f64;
        }
        out[u] = acc as f32;
    }
    out
}

/// 1-D inverse (DCT-III) of a vector (reference/tests).
pub fn idct1d(y: &[f32]) -> Vec<f32> {
    let n = y.len();
    let b = basis(n);
    let mut out = vec![0.0f32; n];
    for i in 0..n {
        let mut acc = 0.0f64;
        for u in 0..n {
            acc += b.mat[u * n + i] as f64 * y[u] as f64;
        }
        out[i] = acc as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::tensor::Tensor;

    #[test]
    fn basis_is_orthonormal() {
        for &n in &[1usize, 2, 4, 7, 14, 16] {
            let b = basis(n);
            // D · Dᵀ = I
            for r in 0..n {
                for c in 0..n {
                    let mut acc = 0.0f64;
                    for k in 0..n {
                        acc += b.mat[r * n + k] as f64 * b.mat[c * n + k] as f64;
                    }
                    let expect = if r == c { 1.0 } else { 0.0 };
                    assert!(
                        (acc - expect).abs() < 1e-5,
                        "n={n} ({r},{c}) got {acc}"
                    );
                }
            }
        }
    }

    #[test]
    fn dc_component_of_constant_signal() {
        // DCT-II of a constant c over n points: X[0] = c*sqrt(n), rest 0.
        let n = 8;
        let x = vec![3.0f32; n];
        let y = dct1d(&x);
        assert!((y[0] - 3.0 * (n as f32).sqrt()).abs() < 1e-4);
        for &v in &y[1..] {
            assert!(v.abs() < 1e-5);
        }
    }

    #[test]
    fn roundtrip_1d() {
        let mut rng = Pcg32::seeded(1);
        let x: Vec<f32> = (0..13).map(|_| rng.normal()).collect();
        let back = idct1d(&dct1d(&x));
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn roundtrip_2d() {
        let mut rng = Pcg32::seeded(2);
        let (m, n) = (14, 10);
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut t = Dct2d::new(m, n);
        let mut y = vec![0.0f32; m * n];
        let mut back = vec![0.0f32; m * n];
        t.forward(&x, &mut y);
        t.inverse(&y, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        // Orthonormal transform preserves sum of squares.
        let mut rng = Pcg32::seeded(3);
        let (m, n) = (8, 8);
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut t = Dct2d::new(m, n);
        let mut y = vec![0.0f32; m * n];
        t.forward(&x, &mut y);
        let ex: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        let ey: f64 = y.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((ex - ey).abs() / ex < 1e-5);
    }

    #[test]
    fn tensor_roundtrip() {
        let mut rng = Pcg32::seeded(4);
        let x = Tensor::randn(&[2, 3, 6, 5], 1.0, &mut rng);
        let y = Dct2d::forward_tensor(&x);
        let back = Dct2d::inverse_tensor(&y);
        assert!(x.max_abs_diff(&back) < 1e-4);
    }

    #[test]
    fn smooth_signal_concentrates_low_freq() {
        // A smooth ramp should put most energy into low-index coefficients.
        let n = 16;
        let x: Vec<f32> = (0..n).map(|i| i as f32 / n as f32).collect();
        let y = dct1d(&x);
        let total: f64 = y.iter().map(|&v| (v as f64).powi(2)).sum();
        let low: f64 = y[..4].iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(low / total > 0.99, "low fraction {}", low / total);
    }
}
