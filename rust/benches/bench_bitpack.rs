//! Bit-packing micro-benchmark — the innermost loop of every codec.
//! §Perf (L3) target: well above 100 MB/s so packing never gates the wire.

use slfac::bench::{black_box, Bencher};
use slfac::quant::{pack_uniform, unpack_uniform, BitReader, BitWriter};
use slfac::rng::Pcg32;

fn main() {
    let mut b = Bencher::new();
    let n = 100_352; // one (32,16,14,14) tensor's element count
    let mut rng = Pcg32::seeded(3);

    for bits in [2u32, 4, 8, 12] {
        let vals: Vec<u32> = (0..n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
        let packed = pack_uniform(&vals, bits);
        b.section(&format!("{bits}-bit, {n} values ({} B packed)", packed.len()));
        b.bench_items(&format!("pack/{bits}bit"), n, || {
            black_box(pack_uniform(black_box(&vals), bits));
        });
        b.bench_items(&format!("unpack/{bits}bit"), n, || {
            black_box(unpack_uniform(black_box(&packed), bits, n));
        });
    }

    // mixed-width stream (the FQC case: per-channel widths differ)
    b.section("mixed widths (FQC-style interleaving)");
    let widths: Vec<u32> = (0..n).map(|i| if i % 196 < 20 { 8 } else { 2 }).collect();
    let vals: Vec<u32> = widths
        .iter()
        .map(|&w| rng.next_u32() & ((1 << w) - 1))
        .collect();
    b.bench_items("pack/mixed", n, || {
        let mut w = BitWriter::with_capacity(n);
        for (&v, &bits) in vals.iter().zip(&widths) {
            w.put(v, bits);
        }
        black_box(w.finish());
    });
    let mut w = BitWriter::new();
    for (&v, &bits) in vals.iter().zip(&widths) {
        w.put(v, bits);
    }
    let buf = w.finish();
    b.bench_items("unpack/mixed", n, || {
        let mut r = BitReader::new(black_box(&buf));
        let mut acc = 0u32;
        for &bits in &widths {
            acc ^= r.get(bits);
        }
        black_box(acc);
    });
}
