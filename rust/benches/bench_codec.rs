//! Codec benchmark — the paper's communication-efficiency table, measured.
//!
//! For every codec: compression + decompression throughput on cut-layer
//! tensors (the L3 wire-path hot spot), wire bytes, compression ratio, and
//! reconstruction fidelity. These rows back EXPERIMENTS.md §Comm-volume
//! and §Perf (L3).
//!
//! Run: `cargo bench --bench bench_codec` (SLFAC_BENCH_MS trims time).

use slfac::bench::{black_box, Bencher};
use slfac::codec::{self, CodecParams};
use slfac::dct::Dct2d;

fn main() {
    let mut b = Bencher::new();
    let params = CodecParams::default();

    for shape in [[32usize, 16, 14, 14], [32, 16, 16, 16]] {
        let raw_bytes = shape.iter().product::<usize>() * 4;
        let x = codec::smooth_activations(&shape, 42);
        let coeffs = Dct2d::forward_tensor(&x);
        b.section(&format!(
            "codec compress+decompress, activations {shape:?} ({} KiB raw)",
            raw_bytes / 1024
        ));
        println!(
            "{:<44} {:>12} {:>8} {:>9}",
            "", "wire bytes", "ratio", "rel err"
        );
        for name in codec::ALL_CODECS {
            let c = codec::by_name(name, &params).unwrap();
            let input = if c.frequency_domain() { &coeffs } else { &x };
            let payload = c.compress(input).unwrap();
            let back = c.decompress(&payload).unwrap();
            let err = if c.frequency_domain() {
                Dct2d::inverse_tensor(&back).rel_l2_error(&x)
            } else {
                back.rel_l2_error(&x)
            };

            b.bench_bytes(&format!("{name}/compress"), raw_bytes, || {
                black_box(c.compress(black_box(input)).unwrap());
            });
            b.bench_bytes(&format!("{name}/decompress"), raw_bytes, || {
                black_box(c.decompress(black_box(&payload)).unwrap());
            });
            println!(
                "{:<44} {:>12} {:>7.1}x {:>9.4}",
                format!("  -> {name} wire stats"),
                payload.wire_bytes(),
                payload.compression_ratio(),
                err
            );
        }
    }

    // end-to-end spatial round trip for the paper's method (includes DCT)
    b.section("slfac full spatial roundtrip (incl. Rust DCT, standalone mode)");
    let x = codec::smooth_activations(&[32, 16, 14, 14], 1);
    let c = codec::by_name("slfac", &params).unwrap();
    b.bench_bytes("slfac/spatial-roundtrip", x.numel() * 4, || {
        black_box(codec::roundtrip_spatial(c.as_ref(), black_box(&x)).unwrap());
    });
}
