//! Rust DCT benchmark (the standalone-codec / golden-test path; the wire
//! path runs the transform inside XLA via the Pallas kernel).
//! §Perf (L1/L3 comparison): Rust matrix DCT vs plane sizes.

use slfac::bench::{black_box, Bencher};
use slfac::dct::Dct2d;
use slfac::rng::Pcg32;
use slfac::tensor::Tensor;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg32::seeded(5);

    for (m, n) in [(8usize, 8usize), (14, 14), (16, 16), (28, 28)] {
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut t = Dct2d::new(m, n);
        let mut out = vec![0.0f32; m * n];
        b.section(&format!("single plane {m}x{n}"));
        b.bench_items(&format!("forward/{m}x{n}"), m * n, || {
            t.forward(black_box(&x), &mut out);
            black_box(&out);
        });
        b.bench_items(&format!("inverse/{m}x{n}"), m * n, || {
            t.inverse(black_box(&x), &mut out);
            black_box(&out);
        });
    }

    b.section("full activation tensor (32,16,14,14)");
    let x = Tensor::randn(&[32, 16, 14, 14], 1.0, &mut rng);
    b.bench_bytes("forward_tensor", x.numel() * 4, || {
        black_box(Dct2d::forward_tensor(black_box(&x)));
    });
    b.bench_bytes("inverse_tensor", x.numel() * 4, || {
        black_box(Dct2d::inverse_tensor(black_box(&x)));
    });
}
