//! End-to-end round benchmarks.
//!
//! Section 1 (`engine`; always runs): the **sequential-vs-parallel round
//! engine** comparison on the sim executor backend at 1/4/16 devices —
//! the headline number for the `workers` knob. Parallelism is
//! bit-transparent, so this measures pure wall-clock.
//!
//! Section 2 (`async`; always runs): **transport scheduler scenarios** at
//! 64–256 devices — sync lockstep vs event-driven async under
//! heterogeneous `wifi/lte` fleets with `wait-all`, `deadline-drop`, and
//! `quorum` straggler policies. This is the CI smoke surface for the
//! async scheduler.
//!
//! Section 3 (`xla`; requires `make artifacts`): one full split-learning
//! round over real PJRT artifacts per codec — client_fwd, compress,
//! uplink, idct, server_step, compress, downlink, client_step.
//!
//! `SLFAC_BENCH_ONLY=engine|async|xla` restricts the run to one section
//! (CI uses this to smoke the async scenarios in isolation).

use slfac::bench::{BenchResult, Bencher};
use slfac::config::ExperimentConfig;
use slfac::coordinator::Trainer;
use slfac::runtime::{write_sim_manifest, ExecutorHandle, SimManifestSpec};
use slfac::transport::{ClientSampling, SchedulerKind, StragglerPolicy, UplinkMode};

const SIM_BATCH: usize = 8;

fn sim_cfg(dir: &str, codec: &str, devices: usize, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("bench_sim_{codec}_{devices}d_{workers}w"),
        codec: codec.into(),
        devices,
        workers,
        rounds: 1,
        batches_per_round: 2,
        batch_size: SIM_BATCH,
        train_samples: 40 * devices,
        test_samples: SIM_BATCH,
        artifacts_dir: dir.into(),
        ..Default::default()
    }
}

fn bench_sim_engine(b: &mut Bencher) {
    let dir = format!(
        "{}/slfac_bench_sim_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    // heavier cut layer than the tests use, so per-device work dominates
    // thread handoff: act 8x14x14 = 1568 features
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: SIM_BATCH,
            act_channels: 8,
            act_hw: 14,
        }],
    )
    .unwrap();
    let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".to_string()]).unwrap();

    b.section("round engine: sequential (workers=1) vs parallel (workers=4), sim backend");
    for codec in ["identity", "slfac"] {
        for devices in [1usize, 4, 16] {
            let mut seq: Option<BenchResult> = None;
            for workers in [1usize, 4] {
                if workers > devices {
                    continue;
                }
                let mut trainer =
                    Trainer::new(sim_cfg(&dir, codec, devices, workers), exec.clone())
                        .unwrap();
                // warm once (first-touch allocations), then measure rounds
                let _ = trainer.run().unwrap();
                let r = b
                    .bench(
                        &format!("sim round/{codec}/devices={devices}/workers={workers}"),
                        || {
                            let _ = trainer.run().unwrap();
                        },
                    )
                    .clone();
                match workers {
                    1 => seq = Some(r),
                    _ => {
                        if let Some(seq) = &seq {
                            println!(
                                "    -> parallel speedup x{:.2} ({codec}, {devices} devices)",
                                r.speedup_vs(seq)
                            );
                        }
                    }
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

fn bench_xla_round(b: &mut Bencher) {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP xla round bench: run `make artifacts` first");
        return;
    }
    // executor shared across codecs: compile once
    let exec = ExecutorHandle::spawn("artifacts", &["mnist".to_string()]).unwrap();

    b.section("one communication round (5 devices x 2 batches, mnist, xla backend)");
    for codec in ["identity", "slfac", "pq-sl", "tk-sl", "fc-sl"] {
        let mk = || ExperimentConfig {
            name: format!("bench_{codec}"),
            codec: codec.into(),
            rounds: 1,
            batches_per_round: 2,
            train_samples: 1000,
            test_samples: 64,
            ..Default::default()
        };
        // warm once to amortize first-execution copies, then measure rounds.
        let mut trainer = Trainer::new(mk(), exec.clone()).unwrap();
        let _ = trainer.run().unwrap();
        let mut trainer = Trainer::new(mk(), exec.clone()).unwrap();
        b.bench(&format!("round/{codec}"), || {
            let _ = trainer.run().unwrap();
        });
    }

    println!("\nexecutor totals:");
    let stats = exec.stats().unwrap();
    for (key, (n, t)) in &stats.per_artifact {
        println!(
            "  {key:<22} {n:>6} execs  {:>9.3}s  ({:>7.2}ms mean)",
            t.as_secs_f64(),
            t.as_secs_f64() * 1e3 / (*n as f64).max(1.0)
        );
    }
}

fn bench_async_scenarios(b: &mut Bencher) {
    let dir = format!(
        "{}/slfac_bench_async_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: SIM_BATCH,
            act_channels: 2,
            act_hw: 4,
        }],
    )
    .unwrap();
    let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".to_string()]).unwrap();

    b.section("transport schedulers: 64-256 devices, wifi/lte mix, straggler policies");
    for devices in [64usize, 128, 256] {
        let scenarios: [(&str, SchedulerKind, StragglerPolicy); 4] = [
            ("sync", SchedulerKind::Sync, StragglerPolicy::WaitAll),
            ("async/wait-all", SchedulerKind::Async, StragglerPolicy::WaitAll),
            (
                "async/deadline-drop",
                SchedulerKind::Async,
                // generous enough for the wifi half, drops most lte
                // stragglers mid-round
                StragglerPolicy::DeadlineDrop { deadline_s: 0.05 },
            ),
            (
                "async/quorum",
                SchedulerKind::Async,
                StragglerPolicy::Quorum { k: devices / 2 },
            ),
        ];
        for (label, kind, policy) in scenarios {
            let mut cfg = sim_cfg(&dir, "slfac", devices, 0);
            cfg.name = format!("bench_{}_{}d", label.replace('/', "_"), devices);
            cfg.batches_per_round = 1;
            cfg.train_samples = 16 * devices;
            cfg.scheduler = kind;
            cfg.profile = "wifi/lte".into();
            cfg.straggler = policy;
            let mut trainer = Trainer::new(cfg, exec.clone()).unwrap();
            let _ = trainer.run().unwrap(); // warm
            b.bench(&format!("round/{label}/devices={devices}"), || {
                let _ = trainer.run().unwrap();
            });
        }
    }

    b.section("contention model: shared uplink, server service, client sampling");
    for devices in [64usize, 256] {
        let contention: [(&str, UplinkMode, f64, ClientSampling); 3] = [
            // every uplink contends for one 100 Mbit/s cell + a busy server
            ("shared+service", UplinkMode::Shared, 0.001, ClientSampling::Full),
            // classic FedAvg-style 25% participation
            ("sampled-25pct", UplinkMode::Private, 0.0, ClientSampling::Fraction(0.25)),
            // the full congestion stack
            (
                "shared+service+sampled",
                UplinkMode::Shared,
                0.001,
                ClientSampling::Fraction(0.25),
            ),
        ];
        for (label, uplink, service_s, sampling) in contention {
            let mut cfg = sim_cfg(&dir, "slfac", devices, 0);
            cfg.name = format!("bench_{}_{}d", label.replace('+', "_"), devices);
            cfg.batches_per_round = 1;
            cfg.train_samples = 16 * devices;
            cfg.scheduler = SchedulerKind::Async;
            cfg.uplink = uplink;
            cfg.server_service_s = service_s;
            cfg.sampling = sampling;
            let mut trainer = Trainer::new(cfg, exec.clone()).unwrap();
            let _ = trainer.run().unwrap(); // warm
            b.bench(&format!("round/{label}/devices={devices}"), || {
                let _ = trainer.run().unwrap();
            });
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

fn main() {
    let mut b = Bencher::new();
    let only = std::env::var("SLFAC_BENCH_ONLY").unwrap_or_default();
    if !only.is_empty() && !["engine", "async", "xla"].contains(&only.as_str()) {
        // a CI typo must fail loudly, not silently run zero sections
        eprintln!("SLFAC_BENCH_ONLY='{only}' is not one of engine|async|xla");
        std::process::exit(2);
    }
    let want = |section: &str| only.is_empty() || only == section;
    if want("engine") {
        bench_sim_engine(&mut b);
    }
    if want("async") {
        bench_async_scenarios(&mut b);
    }
    if want("xla") {
        bench_xla_round(&mut b);
    }
}
