//! End-to-end round benchmark over real artifacts (the headline L3 number):
//! one full split-learning communication round — client_fwd, compress,
//! uplink, idct, server_step, compress, downlink, client_step — per codec.
//!
//! Requires `make artifacts`; exits with a notice otherwise.

use slfac::bench::Bencher;
use slfac::config::ExperimentConfig;
use slfac::coordinator::Trainer;
use slfac::runtime::ExecutorHandle;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP bench_round: run `make artifacts` first");
        return;
    }
    let mut b = Bencher::new();
    // executor shared across codecs: compile once
    let exec = ExecutorHandle::spawn("artifacts", &["mnist".to_string()]).unwrap();

    b.section("one communication round (5 devices x 2 batches, mnist)");
    for codec in ["identity", "slfac", "pq-sl", "tk-sl", "fc-sl"] {
        let cfg = ExperimentConfig {
            name: format!("bench_{codec}"),
            codec: codec.into(),
            rounds: 1,
            batches_per_round: 2,
            train_samples: 1000,
            test_samples: 64,
            ..Default::default()
        };
        let mut trainer = Trainer::new(cfg, exec.clone()).unwrap();
        // warm once to amortize first-execution copies, then measure rounds.
        let _ = trainer.run().unwrap();
        let mut trainer = Trainer::new(
            ExperimentConfig {
                name: format!("bench_{codec}"),
                codec: codec.into(),
                rounds: 1,
                batches_per_round: 2,
                train_samples: 1000,
                test_samples: 64,
                ..Default::default()
            },
            exec.clone(),
        )
        .unwrap();
        b.bench(&format!("round/{codec}"), || {
            let _ = trainer.run().unwrap();
        });
    }

    println!("\nexecutor totals:");
    let stats = exec.stats().unwrap();
    for (key, (n, t)) in &stats.per_artifact {
        println!(
            "  {key:<22} {n:>6} execs  {:>9.3}s  ({:>7.2}ms mean)",
            t.as_secs_f64(),
            t.as_secs_f64() * 1e3 / (*n as f64).max(1.0)
        );
    }
}
