//! End-to-end round benchmarks.
//!
//! Section 1 (`engine`; always runs): the **sequential-vs-parallel round
//! engine** comparison on the sim executor backend at 1/4/16 devices —
//! the headline number for the `workers` knob. Parallelism is
//! bit-transparent, so this measures pure wall-clock.
//!
//! Section 2 (`async`; always runs): **transport scheduler scenarios** at
//! 64–256 devices — sync lockstep vs event-driven async under
//! heterogeneous `wifi/lte` fleets with `wait-all`, `deadline-drop`, and
//! `quorum` straggler policies. This is the CI smoke surface for the
//! async scheduler.
//!
//! Section 3 (`codec`; always runs): **codec kernel micro-benches** —
//! compress+decompress MB/s per codec on an MNIST-scale 14×14 and a
//! CIFAR-scale 32×32 plane, the slfac fused-vs-reference kernel ratio,
//! and fast-vs-reference full async rounds at 64/256 devices. Results
//! additionally land in machine-readable `BENCH_codec.json` so future
//! PRs get a perf trajectory.
//!
//! Section 4 (`compute`; always runs): **compute-backend benches** —
//! blocked-vs-reference kernel GFLOP/s, resident-vs-artifact single
//! device steps, and fast-vs-reference full async rounds at 64/256
//! devices. Results additionally land in `BENCH_compute.json`.
//!
//! Section 5 (`fleet`; always runs): **fleet-scale transport rounds** —
//! cohort-compressed scheduler rounds over [`FleetOps`] (pure transport,
//! no model compute) at 10k / 100k / 1M devices, sync and async. The
//! headline rounds/s numbers land in `BENCH_fleet.json`; this is the
//! acceptance surface for the million-device simulation.
//!
//! Section 6 (`xla`; requires `make artifacts`): one full split-learning
//! round over real PJRT artifacts per codec — client_fwd, compress,
//! uplink, idct, server_step, compress, downlink, client_step.
//!
//! `SLFAC_BENCH_ONLY=engine|async|codec|compute|fleet|xla` restricts the
//! run to one section (CI uses this to smoke the async scenarios, the
//! codec kernels, the compute backend, and the fleet scale in isolation).
//! An unknown section name is an error listing the valid names — it does
//! not silently run zero sections.
//!
//! [`FleetOps`]: slfac::transport::FleetOps

use slfac::bench::{black_box, report, BenchResult, Bencher, SectionFilter};
use slfac::codec::{self, CodecParams, CodecScratch, Payload};
use slfac::config::ExperimentConfig;
use slfac::coordinator::Trainer;
use slfac::dct::Dct2d;
use slfac::json::Json;
use slfac::rng::Pcg32;
use slfac::runtime::compute as ck;
use slfac::runtime::{write_sim_manifest, ExecutorHandle, HostTensor, SimManifestSpec};
use slfac::tensor::Tensor;
use slfac::transport::fleet::FleetCohort;
use slfac::transport::{
    AsyncEventScheduler, ClientSampling, FaultConfig, FaultPlan, FleetOps, RoundScheduler,
    SchedulerKind, StragglerPolicy, SyncEventScheduler, UplinkMode,
};
use std::collections::BTreeMap;

const SIM_BATCH: usize = 8;

fn sim_cfg(dir: &str, codec: &str, devices: usize, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("bench_sim_{codec}_{devices}d_{workers}w"),
        codec: codec.into(),
        devices,
        workers,
        rounds: 1,
        batches_per_round: 2,
        batch_size: SIM_BATCH,
        train_samples: 40 * devices,
        test_samples: SIM_BATCH,
        artifacts_dir: dir.into(),
        ..Default::default()
    }
}

fn bench_sim_engine(b: &mut Bencher) {
    let dir = format!(
        "{}/slfac_bench_sim_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    // heavier cut layer than the tests use, so per-device work dominates
    // thread handoff: act 8x14x14 = 1568 features
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: SIM_BATCH,
            act_channels: 8,
            act_hw: 14,
        }],
    )
    .unwrap();
    let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".to_string()]).unwrap();

    b.section("round engine: sequential (workers=1) vs parallel (workers=4), sim backend");
    for codec in ["identity", "slfac"] {
        for devices in [1usize, 4, 16] {
            let mut seq: Option<BenchResult> = None;
            for workers in [1usize, 4] {
                if workers > devices {
                    continue;
                }
                let mut trainer =
                    Trainer::new(sim_cfg(&dir, codec, devices, workers), exec.clone())
                        .unwrap();
                // warm once (first-touch allocations), then measure rounds
                let _ = trainer.run().unwrap();
                let r = b
                    .bench(
                        &format!("sim round/{codec}/devices={devices}/workers={workers}"),
                        || {
                            let _ = trainer.run().unwrap();
                        },
                    )
                    .clone();
                match workers {
                    1 => seq = Some(r),
                    _ => {
                        if let Some(seq) = &seq {
                            println!(
                                "    -> parallel speedup x{:.2} ({codec}, {devices} devices)",
                                r.speedup_vs(seq)
                            );
                        }
                    }
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

fn bench_xla_round(b: &mut Bencher) {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP xla round bench: run `make artifacts` first");
        return;
    }
    // executor shared across codecs: compile once
    let exec = ExecutorHandle::spawn("artifacts", &["mnist".to_string()]).unwrap();

    b.section("one communication round (5 devices x 2 batches, mnist, xla backend)");
    for codec in ["identity", "slfac", "pq-sl", "tk-sl", "fc-sl"] {
        let mk = || ExperimentConfig {
            name: format!("bench_{codec}"),
            codec: codec.into(),
            rounds: 1,
            batches_per_round: 2,
            train_samples: 1000,
            test_samples: 64,
            ..Default::default()
        };
        // warm once to amortize first-execution copies, then measure rounds.
        let mut trainer = Trainer::new(mk(), exec.clone()).unwrap();
        let _ = trainer.run().unwrap();
        let mut trainer = Trainer::new(mk(), exec.clone()).unwrap();
        b.bench(&format!("round/{codec}"), || {
            let _ = trainer.run().unwrap();
        });
    }

    println!("\nexecutor totals:");
    let stats = exec.stats().unwrap();
    for (key, (n, t)) in &stats.per_artifact {
        println!(
            "  {key:<22} {n:>6} execs  {:>9.3}s  ({:>7.2}ms mean)",
            t.as_secs_f64(),
            t.as_secs_f64() * 1e3 / (*n as f64).max(1.0)
        );
    }
}

fn bench_async_scenarios(b: &mut Bencher) {
    let dir = format!(
        "{}/slfac_bench_async_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: SIM_BATCH,
            act_channels: 2,
            act_hw: 4,
        }],
    )
    .unwrap();
    let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".to_string()]).unwrap();

    b.section("transport schedulers: 64-256 devices, wifi/lte mix, straggler policies");
    for devices in [64usize, 128, 256] {
        let scenarios: [(&str, SchedulerKind, StragglerPolicy); 4] = [
            ("sync", SchedulerKind::Sync, StragglerPolicy::WaitAll),
            ("async/wait-all", SchedulerKind::Async, StragglerPolicy::WaitAll),
            (
                "async/deadline-drop",
                SchedulerKind::Async,
                // generous enough for the wifi half, drops most lte
                // stragglers mid-round
                StragglerPolicy::DeadlineDrop { deadline_s: 0.05 },
            ),
            (
                "async/quorum",
                SchedulerKind::Async,
                StragglerPolicy::Quorum { k: devices / 2 },
            ),
        ];
        for (label, kind, policy) in scenarios {
            let mut cfg = sim_cfg(&dir, "slfac", devices, 0);
            cfg.name = format!("bench_{}_{}d", label.replace('/', "_"), devices);
            cfg.batches_per_round = 1;
            cfg.train_samples = 16 * devices;
            cfg.scheduler = kind;
            cfg.profile = "wifi/lte".into();
            cfg.straggler = policy;
            let mut trainer = Trainer::new(cfg, exec.clone()).unwrap();
            let _ = trainer.run().unwrap(); // warm
            b.bench(&format!("round/{label}/devices={devices}"), || {
                let _ = trainer.run().unwrap();
            });
        }
    }

    b.section("contention model: shared uplink, server service, client sampling");
    for devices in [64usize, 256] {
        let contention: [(&str, UplinkMode, f64, ClientSampling); 3] = [
            // every uplink contends for one 100 Mbit/s cell + a busy server
            ("shared+service", UplinkMode::Shared, 0.001, ClientSampling::Full),
            // classic FedAvg-style 25% participation
            ("sampled-25pct", UplinkMode::Private, 0.0, ClientSampling::Fraction(0.25)),
            // the full congestion stack
            (
                "shared+service+sampled",
                UplinkMode::Shared,
                0.001,
                ClientSampling::Fraction(0.25),
            ),
        ];
        for (label, uplink, service_s, sampling) in contention {
            let mut cfg = sim_cfg(&dir, "slfac", devices, 0);
            cfg.name = format!("bench_{}_{}d", label.replace('+', "_"), devices);
            cfg.batches_per_round = 1;
            cfg.train_samples = 16 * devices;
            cfg.scheduler = SchedulerKind::Async;
            cfg.uplink = uplink;
            cfg.server_service_s = service_s;
            cfg.sampling = sampling;
            let mut trainer = Trainer::new(cfg, exec.clone()).unwrap();
            let _ = trainer.run().unwrap(); // warm
            b.bench(&format!("round/{label}/devices={devices}"), || {
                let _ = trainer.run().unwrap();
            });
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// One micro-bench row destined for `BENCH_codec.json`.
fn micro_row(label: &str, shape: &[usize], op: &str, r: &BenchResult, payload: &Payload) -> Json {
    let mut m = BTreeMap::new();
    m.insert("codec".to_string(), Json::Str(label.to_string()));
    m.insert(
        "shape".to_string(),
        Json::Str(
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("x"),
        ),
    );
    m.insert("op".to_string(), Json::Str(op.to_string()));
    m.insert("median_ns".to_string(), Json::Num(r.median.as_nanos() as f64));
    m.insert("mb_per_s".to_string(), Json::Num(r.mb_per_s().unwrap_or(0.0)));
    m.insert("wire_bytes".to_string(), Json::Num(payload.wire_bytes() as f64));
    m.insert(
        "compression_ratio".to_string(),
        Json::Num(payload.compression_ratio()),
    );
    Json::Obj(m)
}

/// Section 3: codec kernel micro-benches + fast-vs-reference rounds, with
/// machine-readable output (`BENCH_codec.json`).
fn bench_codec_kernels(b: &mut Bencher) {
    let mut micro_rows: Vec<Json> = Vec::new();
    let mut kernel_ratios = BTreeMap::new();

    for shape in [[8usize, 16, 14, 14], [8, 16, 32, 32]] {
        let raw_bytes = shape.iter().product::<usize>() * 4;
        let x = codec::smooth_activations(&shape, 42);
        let coeffs = Dct2d::forward_tensor(&x);
        b.section(&format!(
            "codec kernels: compress+decompress, activations {shape:?} ({} KiB raw)",
            raw_bytes / 1024
        ));

        // every registered codec on its fused/default path, plus the slfac
        // reference kernel for the fast-vs-reference ratio
        let mut variants: Vec<(String, Box<dyn codec::ActivationCodec>)> = codec::ALL_CODECS
            .iter()
            .map(|name| {
                let c = codec::by_name(name, &CodecParams::default()).unwrap();
                (name.to_string(), c)
            })
            .collect();
        let ref_params = CodecParams {
            fast_path: false,
            ..Default::default()
        };
        variants.push((
            "slfac-reference".to_string(),
            codec::by_name("slfac", &ref_params).unwrap(),
        ));

        let mut medians: BTreeMap<String, (f64, f64)> = BTreeMap::new();
        for (label, c) in &variants {
            let input = if c.frequency_domain() { &coeffs } else { &x };
            let mut scratch = CodecScratch::new();
            let mut rng = Pcg32::seeded(7);
            let mut payload = Payload::empty();
            c.compress_into(input, &mut rng, &mut scratch, &mut payload)
                .unwrap();
            let rc = b
                .bench_bytes(&format!("{label}/compress"), raw_bytes, || {
                    // the body buffer recycles through `payload` itself
                    c.compress_into(black_box(input), &mut rng, &mut scratch, &mut payload)
                        .unwrap();
                })
                .clone();
            let mut out = Tensor::zeros(&[1]);
            let rd = b
                .bench_bytes(&format!("{label}/decompress"), raw_bytes, || {
                    c.decompress_into(black_box(&payload), &mut scratch, &mut out)
                        .unwrap();
                })
                .clone();
            micro_rows.push(micro_row(label, &shape, "compress", &rc, &payload));
            micro_rows.push(micro_row(label, &shape, "decompress", &rd, &payload));
            medians.insert(
                label.clone(),
                (rc.median.as_secs_f64(), rd.median.as_secs_f64()),
            );
        }
        if let (Some(fast), Some(reference)) =
            (medians.get("slfac"), medians.get("slfac-reference"))
        {
            let shape_key = format!("{}x{}", shape[2], shape[3]);
            println!(
                "    -> slfac fused-vs-reference: compress x{:.2}, decompress x{:.2} ({shape_key})",
                reference.0 / fast.0.max(1e-12),
                reference.1 / fast.1.max(1e-12),
            );
            kernel_ratios.insert(
                format!("compress_{shape_key}"),
                Json::Num(reference.0 / fast.0.max(1e-12)),
            );
            kernel_ratios.insert(
                format!("decompress_{shape_key}"),
                Json::Num(reference.1 / fast.1.max(1e-12)),
            );
        }
    }

    // fast vs reference through full async rounds at fleet scale — the
    // acceptance-criteria numbers for the 64/256-device scenarios
    b.section("slfac fast vs reference kernels: async wifi/lte rounds, 64/256 devices");
    let dir = format!(
        "{}/slfac_bench_codec_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: SIM_BATCH,
            act_channels: 8,
            act_hw: 14,
        }],
    )
    .unwrap();
    let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".to_string()]).unwrap();
    let mut round_rows: Vec<Json> = Vec::new();
    for devices in [64usize, 256] {
        let mut medians: Vec<f64> = Vec::new();
        for (label, fast) in [("fast", true), ("reference", false)] {
            let mut cfg = sim_cfg(&dir, "slfac", devices, 0);
            cfg.name = format!("bench_codec_{label}_{devices}d");
            cfg.batches_per_round = 1;
            cfg.train_samples = 16 * devices;
            cfg.scheduler = SchedulerKind::Async;
            cfg.profile = "wifi/lte".into();
            cfg.codec_params.fast_path = fast;
            let mut trainer = Trainer::new(cfg, exec.clone()).unwrap();
            let _ = trainer.run().unwrap(); // warm
            let r = b
                .bench(&format!("round/slfac-{label}/devices={devices}"), || {
                    let _ = trainer.run().unwrap();
                })
                .clone();
            medians.push(r.median.as_secs_f64());
        }
        let speedup = medians[1] / medians[0].max(1e-12);
        println!("    -> fast-path round speedup x{speedup:.2} ({devices} devices)");
        let mut m = BTreeMap::new();
        m.insert("devices".to_string(), Json::Num(devices as f64));
        m.insert("fast_round_s".to_string(), Json::Num(medians[0]));
        m.insert("reference_round_s".to_string(), Json::Num(medians[1]));
        m.insert("speedup".to_string(), Json::Num(speedup));
        round_rows.push(Json::Obj(m));
    }
    let _ = std::fs::remove_dir_all(&dir);

    // machine-readable trajectory file
    let mut root = BTreeMap::new();
    root.insert("micro".to_string(), Json::Arr(micro_rows));
    root.insert(
        "slfac_fast_vs_reference".to_string(),
        Json::Obj(kernel_ratios),
    );
    root.insert("rounds".to_string(), Json::Arr(round_rows));
    let path = "BENCH_codec.json";
    report::write(path, &report::versioned("bench-codec", 1, root))
        .expect("write BENCH_codec.json");
    println!("\ncodec bench results -> {path}");
}

/// Section 5: compute-backend benches — per-kernel GFLOP/s (blocked vs
/// reference), fast-vs-reference single device steps, and fast-vs-reference
/// full async rounds at 64/256 devices. Machine-readable output lands in
/// `BENCH_compute.json` (the compute twin of `BENCH_codec.json`).
fn bench_compute(b: &mut Bencher) {
    let mut kernel_rows: Vec<Json> = Vec::new();

    // --- per-kernel GFLOP/s: the MNIST-scale shapes the sim model runs ---
    b.section("compute kernels: blocked fast vs reference (GFLOP/s)");
    let gflops = |flops: f64, r: &BenchResult| flops / r.median.as_secs_f64().max(1e-12) / 1e9;
    let mut rng = Pcg32::seeded(40);
    let mut randn = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal()).collect() };
    // (label, batch, k, n): client fwd, server logits, client backward grad
    for (label, bsz, k, n) in [
        ("fwd_gemm/8x784x196", 8usize, 784usize, 196usize),
        ("fwd_gemm/8x784x1568", 8, 784, 1568),
        ("fwd_gemm/8x196x10", 8, 196, 10),
    ] {
        let x = randn(bsz * k);
        let w = randn(k * n);
        let flops = 2.0 * (bsz * k * n) as f64;
        let mut out = vec![0.0f32; bsz * n];
        let rf = b
            .bench(&format!("{label}/fast"), || {
                ck::fwd_gemm(black_box(&x), black_box(&w), bsz, k, n, &mut out);
            })
            .clone();
        let rr = b
            .bench(&format!("{label}/reference"), || {
                black_box(ck::fwd_gemm_ref(black_box(&x), black_box(&w), bsz, k, n));
            })
            .clone();
        println!(
            "    -> {label}: fast {:.2} GFLOP/s vs reference {:.2} GFLOP/s (x{:.2})",
            gflops(flops, &rf),
            gflops(flops, &rr),
            rf.speedup_vs(&rr)
        );
        let mut m = BTreeMap::new();
        m.insert("kernel".to_string(), Json::Str(label.to_string()));
        m.insert("fast_gflops".to_string(), Json::Num(gflops(flops, &rf)));
        m.insert("reference_gflops".to_string(), Json::Num(gflops(flops, &rr)));
        m.insert("speedup".to_string(), Json::Num(rf.speedup_vs(&rr)));
        kernel_rows.push(Json::Obj(m));
    }
    {
        let (bsz, i_dim, j_dim) = (8usize, 784usize, 196usize);
        let a = randn(bsz * i_dim);
        let d = randn(bsz * j_dim);
        let flops = 2.0 * (bsz * i_dim * j_dim) as f64;
        let mut out = vec![0.0f32; i_dim * j_dim];
        let rf = b
            .bench("grad_outer/8x784x196/fast", || {
                ck::grad_outer(black_box(&a), black_box(&d), bsz, i_dim, j_dim, &mut out);
            })
            .clone();
        let rr = b
            .bench("grad_outer/8x784x196/reference", || {
                black_box(ck::grad_outer_ref(black_box(&a), black_box(&d), bsz, i_dim, j_dim));
            })
            .clone();
        let mut m = BTreeMap::new();
        m.insert("kernel".to_string(), Json::Str("grad_outer/8x784x196".to_string()));
        m.insert("fast_gflops".to_string(), Json::Num(gflops(flops, &rf)));
        m.insert("reference_gflops".to_string(), Json::Num(gflops(flops, &rr)));
        m.insert("speedup".to_string(), Json::Num(rf.speedup_vs(&rr)));
        kernel_rows.push(Json::Obj(m));
    }
    {
        let (bsz, feat, classes) = (8usize, 196usize, 10usize);
        let d = randn(bsz * classes);
        let w_s = randn(feat * classes);
        let mut w_s_t = vec![0.0f32; feat * classes];
        for r in 0..feat {
            for c in 0..classes {
                w_s_t[c * feat + r] = w_s[r * classes + c];
            }
        }
        let flops = 2.0 * (bsz * feat * classes) as f64;
        let mut out = vec![0.0f32; bsz * feat];
        let rf = b
            .bench("gact/8x196x10/fast", || {
                ck::gact_fast(black_box(&d), black_box(&w_s_t), bsz, feat, classes, &mut out);
            })
            .clone();
        let rr = b
            .bench("gact/8x196x10/reference", || {
                black_box(ck::gact_ref(black_box(&d), black_box(&w_s), bsz, feat, classes));
            })
            .clone();
        let mut m = BTreeMap::new();
        m.insert("kernel".to_string(), Json::Str("gact/8x196x10".to_string()));
        m.insert("fast_gflops".to_string(), Json::Num(gflops(flops, &rf)));
        m.insert("reference_gflops".to_string(), Json::Num(gflops(flops, &rr)));
        m.insert("speedup".to_string(), Json::Num(rf.speedup_vs(&rr)));
        kernel_rows.push(Json::Obj(m));
    }

    // --- one full device step: resident fast path vs artifact path ---
    b.section("compute step: resident (fast) vs artifact execute (reference)");
    let dir = format!(
        "{}/slfac_bench_compute_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: SIM_BATCH,
            act_channels: 4,
            act_hw: 7,
        }],
    )
    .unwrap();
    let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".to_string()]).unwrap();
    let step_ratio = {
        let res = exec.open_resident("mnist", 1).unwrap().expect("resident");
        let mut rng = Pcg32::seeded(41);
        let x: Vec<f32> = (0..SIM_BATCH * 784).map(|_| rng.normal() * 0.5).collect();
        let y: Vec<i32> = (0..SIM_BATCH).map(|i| (i % 10) as i32).collect();
        let mut wire = Tensor::zeros(&[1]);
        let mut grad = Tensor::zeros(&[1]);
        let rf = b
            .bench("step/fast (fwd+server+bwd, resident)", || {
                res.client_fwd(0, &x, false, &mut wire).unwrap();
                res.server_step(&wire, &y, 0.05, false, &mut grad).unwrap();
                res.client_step(0, &x, &grad, 0.05).unwrap();
            })
            .clone();

        // reference: the artifact protocol with full parameter round trips
        let init = exec.execute("mnist", "init", vec![]).unwrap();
        let mut cp = init[0].clone();
        let mut sp = init[1].clone();
        let zeros = |t: &HostTensor| HostTensor::f32(t.dims(), vec![0.0; t.numel()]);
        let (mut cm, mut sm) = (zeros(&cp), zeros(&sp));
        let xh = HostTensor::f32(&[SIM_BATCH, 1, 28, 28], x.clone());
        let yh = HostTensor::i32(&[SIM_BATCH], y.clone());
        let lr = HostTensor::scalar_f32(0.05);
        let rr = b
            .bench("step/reference (artifact execute)", || {
                let fwd = exec
                    .execute("mnist", "client_fwd", vec![cp.clone(), xh.clone()])
                    .unwrap();
                let out = exec
                    .execute(
                        "mnist",
                        "server_step",
                        vec![
                            sp.clone(),
                            sm.clone(),
                            fwd[0].clone(),
                            yh.clone(),
                            lr.clone(),
                        ],
                    )
                    .unwrap();
                let mut it = out.into_iter();
                sp = it.next().unwrap();
                sm = it.next().unwrap();
                let _loss = it.next().unwrap();
                let _correct = it.next().unwrap();
                let gact = it.next().unwrap();
                let back = exec
                    .execute(
                        "mnist",
                        "client_step",
                        vec![cp.clone(), cm.clone(), xh.clone(), gact, lr.clone()],
                    )
                    .unwrap();
                let mut it = back.into_iter();
                cp = it.next().unwrap();
                cm = it.next().unwrap();
            })
            .clone();
        let ratio = rf.speedup_vs(&rr);
        println!("    -> fast-vs-reference step speedup x{ratio:.2}");
        ratio
    };

    // --- fast vs reference through full async rounds at fleet scale ------
    b.section("compute fast vs reference: async wifi/lte rounds, 64/256 devices");
    let mut round_rows: Vec<Json> = Vec::new();
    for devices in [64usize, 256] {
        let mut medians: Vec<f64> = Vec::new();
        for (label, fast) in [("fast", true), ("reference", false)] {
            let mut cfg = sim_cfg(&dir, "slfac", devices, 0);
            cfg.name = format!("bench_compute_{label}_{devices}d");
            cfg.batches_per_round = 1;
            cfg.train_samples = 16 * devices;
            cfg.scheduler = SchedulerKind::Async;
            cfg.profile = "wifi/lte".into();
            cfg.compute_fast_path = fast;
            let mut trainer = Trainer::new(cfg, exec.clone()).unwrap();
            let _ = trainer.run().unwrap(); // warm
            let r = b
                .bench(&format!("round/compute-{label}/devices={devices}"), || {
                    let _ = trainer.run().unwrap();
                })
                .clone();
            medians.push(r.median.as_secs_f64());
        }
        let speedup = medians[1] / medians[0].max(1e-12);
        println!("    -> compute fast-path round speedup x{speedup:.2} ({devices} devices)");
        let mut m = BTreeMap::new();
        m.insert("devices".to_string(), Json::Num(devices as f64));
        m.insert("fast_round_s".to_string(), Json::Num(medians[0]));
        m.insert("reference_round_s".to_string(), Json::Num(medians[1]));
        m.insert("speedup".to_string(), Json::Num(speedup));
        round_rows.push(Json::Obj(m));
    }
    let _ = std::fs::remove_dir_all(&dir);

    // machine-readable trajectory file
    let mut root = BTreeMap::new();
    root.insert("kernels".to_string(), Json::Arr(kernel_rows));
    let mut step = BTreeMap::new();
    step.insert("fast_vs_reference_speedup".to_string(), Json::Num(step_ratio));
    root.insert("step".to_string(), Json::Obj(step));
    root.insert("rounds".to_string(), Json::Arr(round_rows));
    let path = "BENCH_compute.json";
    report::write(path, &report::versioned("bench-compute", 1, root))
        .expect("write BENCH_compute.json");
    println!("\ncompute bench results -> {path}");
}

/// Section 5: fleet-scale transport rounds — cohort-compressed scheduler
/// rounds over [`FleetOps`] at 10k/100k/1M devices (pure transport, no
/// model compute). Proves a million-device round completes and records
/// rounds/s in `BENCH_fleet.json`.
fn bench_fleet(b: &mut Bencher) {
    b.section("fleet scale: cohort-compressed transport rounds, 10k/100k/1M devices");
    // two cost cohorts (the wifi/lte shape), round-robin like
    // assign_profiles
    let profiles = vec![
        FleetCohort {
            compute_s: 0.002,
            uplink_cost_s: 0.012,
            downlink_s: 0.006,
            uplink_bytes: 12_000,
            downlink_bytes: 6_000,
        },
        FleetCohort {
            compute_s: 0.006,
            uplink_cost_s: 0.045,
            downlink_s: 0.020,
            uplink_bytes: 12_000,
            downlink_bytes: 6_000,
        },
    ];
    let mut rows: Vec<Json> = Vec::new();
    for devices in [10_000usize, 100_000, 1_000_000] {
        let schedulers: [(&str, Box<dyn RoundScheduler>); 2] = [
            ("sync", Box::new(SyncEventScheduler::new())),
            (
                "async/wait-all",
                Box::new(AsyncEventScheduler::new(StragglerPolicy::WaitAll)),
            ),
        ];
        for (label, sched) in schedulers {
            let mut ops = FleetOps::new(devices, 1, profiles.clone());
            ops.set_cohorts(profiles.len());
            ops.set_server_service_s(1e-6);
            // warm once (scratch first-touch) and prove the round completes
            let report = sched.run_round(&mut ops).unwrap();
            assert_eq!(
                report.completed, devices,
                "fleet round must complete every device"
            );
            let r = b
                .bench(&format!("fleet round/{label}/devices={devices}"), || {
                    let _ = sched.run_round(black_box(&mut ops)).unwrap();
                })
                .clone();
            let round_s = r.median.as_secs_f64();
            let rounds_per_s = 1.0 / round_s.max(1e-12);
            println!("    -> {rounds_per_s:.2} rounds/s ({label}, {devices} devices)");
            let mut m = BTreeMap::new();
            m.insert("devices".to_string(), Json::Num(devices as f64));
            m.insert("scheduler".to_string(), Json::Str(label.to_string()));
            m.insert("cohorts".to_string(), Json::Num(profiles.len() as f64));
            m.insert("round_s".to_string(), Json::Num(round_s));
            m.insert("rounds_per_s".to_string(), Json::Num(rounds_per_s));
            rows.push(Json::Obj(m));
        }
    }

    // faulty-fleet row: 10k devices with 5% seeded uplink/downlink loss —
    // the per-device retry path (faulty rounds never cohort-compress), so
    // this also bounds the fault layer's overhead at scale
    {
        let fc = FaultConfig {
            loss_prob: 0.05,
            ..Default::default()
        };
        let devices = 10_000usize;
        let sched = SyncEventScheduler::new();
        let mut ops = FleetOps::new(devices, 1, profiles.clone());
        ops.set_server_service_s(1e-6);
        ops.set_fault(Some(FaultPlan::new(fc, 0xFA17, 0)));
        let report = sched.run_round(&mut ops).unwrap();
        assert!(
            report.retransmits > 0,
            "5% loss over 10k devices must retransmit"
        );
        assert!(report.completed + report.dropped() == devices);
        let r = b
            .bench(&format!("fleet round/sync+faults/devices={devices}"), || {
                let _ = sched.run_round(black_box(&mut ops)).unwrap();
            })
            .clone();
        let round_s = r.median.as_secs_f64();
        let mut m = BTreeMap::new();
        m.insert("devices".to_string(), Json::Num(devices as f64));
        m.insert("scheduler".to_string(), Json::Str("sync+faults".to_string()));
        m.insert("loss_prob".to_string(), Json::Num(fc.loss_prob));
        m.insert("retransmits".to_string(), Json::Num(report.retransmits as f64));
        m.insert("round_s".to_string(), Json::Num(round_s));
        m.insert(
            "rounds_per_s".to_string(),
            Json::Num(1.0 / round_s.max(1e-12)),
        );
        rows.push(Json::Obj(m));
    }

    let mut root = BTreeMap::new();
    root.insert("rounds".to_string(), Json::Arr(rows));
    let path = "BENCH_fleet.json";
    report::write(path, &report::versioned("bench-fleet", 1, root))
        .expect("write BENCH_fleet.json");
    println!("\nfleet bench results -> {path}");
}

fn main() {
    let mut b = Bencher::new();
    // a CI typo must fail loudly, not silently run zero sections
    let filter = match SectionFilter::from_env(
        "SLFAC_BENCH_ONLY",
        &["engine", "async", "codec", "compute", "fleet", "xla"],
    ) {
        Ok(f) => f,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let want = |section: &str| filter.wants(section);
    if want("engine") {
        bench_sim_engine(&mut b);
    }
    if want("async") {
        bench_async_scenarios(&mut b);
    }
    if want("codec") {
        bench_codec_kernels(&mut b);
    }
    if want("compute") {
        bench_compute(&mut b);
    }
    if want("fleet") {
        bench_fleet(&mut b);
    }
    if want("xla") {
        bench_xla_round(&mut b);
    }
}
