//! End-to-end round benchmarks.
//!
//! Section 1 (always runs): the **sequential-vs-parallel round engine**
//! comparison on the sim executor backend at 1/4/16 devices — the headline
//! number for the `workers` knob. Parallelism is bit-transparent, so this
//! measures pure wall-clock.
//!
//! Section 2 (requires `make artifacts`): one full split-learning round
//! over real PJRT artifacts per codec — client_fwd, compress, uplink,
//! idct, server_step, compress, downlink, client_step.

use slfac::bench::{BenchResult, Bencher};
use slfac::config::ExperimentConfig;
use slfac::coordinator::Trainer;
use slfac::runtime::{write_sim_manifest, ExecutorHandle, SimManifestSpec};

const SIM_BATCH: usize = 8;

fn sim_cfg(dir: &str, codec: &str, devices: usize, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("bench_sim_{codec}_{devices}d_{workers}w"),
        codec: codec.into(),
        devices,
        workers,
        rounds: 1,
        batches_per_round: 2,
        batch_size: SIM_BATCH,
        train_samples: 40 * devices,
        test_samples: SIM_BATCH,
        artifacts_dir: dir.into(),
        ..Default::default()
    }
}

fn bench_sim_engine(b: &mut Bencher) {
    let dir = format!(
        "{}/slfac_bench_sim_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    // heavier cut layer than the tests use, so per-device work dominates
    // thread handoff: act 8x14x14 = 1568 features
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: SIM_BATCH,
            act_channels: 8,
            act_hw: 14,
        }],
    )
    .unwrap();
    let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".to_string()]).unwrap();

    b.section("round engine: sequential (workers=1) vs parallel (workers=4), sim backend");
    for codec in ["identity", "slfac"] {
        for devices in [1usize, 4, 16] {
            let mut seq: Option<BenchResult> = None;
            for workers in [1usize, 4] {
                if workers > devices {
                    continue;
                }
                let mut trainer =
                    Trainer::new(sim_cfg(&dir, codec, devices, workers), exec.clone())
                        .unwrap();
                // warm once (first-touch allocations), then measure rounds
                let _ = trainer.run().unwrap();
                let r = b
                    .bench(
                        &format!("sim round/{codec}/devices={devices}/workers={workers}"),
                        || {
                            let _ = trainer.run().unwrap();
                        },
                    )
                    .clone();
                match workers {
                    1 => seq = Some(r),
                    _ => {
                        if let Some(seq) = &seq {
                            println!(
                                "    -> parallel speedup x{:.2} ({codec}, {devices} devices)",
                                r.speedup_vs(seq)
                            );
                        }
                    }
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

fn bench_xla_round(b: &mut Bencher) {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP xla round bench: run `make artifacts` first");
        return;
    }
    // executor shared across codecs: compile once
    let exec = ExecutorHandle::spawn("artifacts", &["mnist".to_string()]).unwrap();

    b.section("one communication round (5 devices x 2 batches, mnist, xla backend)");
    for codec in ["identity", "slfac", "pq-sl", "tk-sl", "fc-sl"] {
        let mk = || ExperimentConfig {
            name: format!("bench_{codec}"),
            codec: codec.into(),
            rounds: 1,
            batches_per_round: 2,
            train_samples: 1000,
            test_samples: 64,
            ..Default::default()
        };
        // warm once to amortize first-execution copies, then measure rounds.
        let mut trainer = Trainer::new(mk(), exec.clone()).unwrap();
        let _ = trainer.run().unwrap();
        let mut trainer = Trainer::new(mk(), exec.clone()).unwrap();
        b.bench(&format!("round/{codec}"), || {
            let _ = trainer.run().unwrap();
        });
    }

    println!("\nexecutor totals:");
    let stats = exec.stats().unwrap();
    for (key, (n, t)) in &stats.per_artifact {
        println!(
            "  {key:<22} {n:>6} execs  {:>9.3}s  ({:>7.2}ms mean)",
            t.as_secs_f64(),
            t.as_secs_f64() * 1e3 / (*n as f64).max(1.0)
        );
    }
}

fn main() {
    let mut b = Bencher::new();
    bench_sim_engine(&mut b);
    bench_xla_round(&mut b);
}
