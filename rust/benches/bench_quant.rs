//! Quantizer benchmark: linear (Eq. 8/9) vs PowerQuant vs EasyQuant —
//! fit + quantize + dequantize over a cut-layer-sized buffer.

use slfac::bench::{black_box, Bencher};
use slfac::quant::{EasyQuant, LinearQuantizer, PowerQuant};
use slfac::rng::Pcg32;

fn main() {
    let mut b = Bencher::new();
    let n = 100_352;
    let mut rng = Pcg32::seeded(7);
    let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let bytes = n * 4;

    b.section("fit (range/exponent/clip search)");
    b.bench_bytes("linear/fit", bytes, || {
        black_box(LinearQuantizer::fit(4, black_box(&data)));
    });
    b.bench_bytes("powerquant/fit", bytes, || {
        black_box(PowerQuant::fit(4, black_box(&data)));
    });
    b.bench_bytes("easyquant/fit", bytes, || {
        black_box(EasyQuant::fit(4, black_box(&data)));
    });

    b.section("quantize + dequantize (4-bit)");
    let lq = LinearQuantizer::fit(4, &data);
    b.bench_items("linear/roundtrip", n, || {
        let mut acc = 0.0f32;
        for &x in &data {
            acc += lq.dequantize(lq.quantize(black_box(x)));
        }
        black_box(acc);
    });
    let pq = PowerQuant::fit(4, &data);
    b.bench_items("powerquant/roundtrip", n, || {
        let mut acc = 0.0f32;
        for &x in &data {
            acc += pq.dequantize(pq.quantize(black_box(x)));
        }
        black_box(acc);
    });
    let eq = EasyQuant::fit(4, &data);
    b.bench_items("easyquant/roundtrip", n, || {
        let mut acc = 0.0f32;
        for &x in &data {
            acc += eq.dequantize(eq.quantize(black_box(x)));
        }
        black_box(acc);
    });
}
