//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! This container has no PJRT/XLA shared library, so the real bindings
//! cannot build here. This crate mirrors the exact API surface
//! `slfac::runtime::executor` uses and fails at **runtime** (from
//! [`PjRtClient::cpu`]) with a clear message. Swapping this path
//! dependency for the real `xla-rs` crate restores the hardware-backed
//! executor without any source change in `slfac`; the in-tree `sim`
//! backend covers tests and benches meanwhile.

use std::fmt;

/// Error type mirroring xla-rs's error (message-only here).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn stub_err<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: built against the offline xla stub — no PJRT runtime is \
         linked (use the sim executor backend, or replace \
         rust/vendor/xla-stub with the real xla-rs crate)"
    )))
}

/// Element dtypes of literals/buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 1-bit predicate.
    Pred,
    /// Signed 32-bit integer.
    S32,
    /// Signed 64-bit integer.
    S64,
    /// IEEE half float.
    F16,
    /// IEEE single float.
    F32,
    /// IEEE double float.
    F64,
}

/// Scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    /// The XLA element type tag for this native type.
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// Array shape: dims + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element type.
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal (opaque in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    shape: ArrayShape,
}

impl Literal {
    /// Scalar literal.
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal {
            shape: ArrayShape {
                dims: vec![],
                ty: T::TY,
            },
        }
    }

    /// Literal from a shape and raw bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, XlaError> {
        Ok(Literal {
            shape: ArrayShape {
                dims: dims.iter().map(|&d| d as i64).collect(),
                ty,
            },
        })
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        stub_err("Literal::to_tuple")
    }

    /// The literal's array shape.
    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        Ok(self.shape.clone())
    }

    /// Copy out typed host data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        stub_err("Literal::to_vec")
    }
}

/// Parsed HLO module (opaque).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        stub_err("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (opaque).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (opaque).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (opaque).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Run the executable over argument literals.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (opaque). In the stub, construction always fails.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU PJRT client. Always errors in the stub build.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        stub_err("PjRtClient::cpu")
    }

    /// Compile a computation for this client.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        stub_err("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_fails_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
    }

    #[test]
    fn literal_shape_plumbing_works() {
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 3],
            &[0u8; 24],
        )
        .unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(Literal::scalar(1i32).array_shape().unwrap().ty(), ElementType::S32);
    }
}
