//! Offline shim of the `anyhow` crate: the API subset this workspace uses,
//! reimplemented with no dependencies so builds never touch a registry.
//!
//! Provided surface: [`Error`], [`Result`], the [`Context`] extension trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Context is stored as a flat message chain (outermost first); `{:#}`
//! formatting prints the full `a: b: c` chain like real anyhow.

use std::fmt;

/// `Result` with a defaulted [`Error`] type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error value (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.chain.iter().enumerate() {
            if i > 0 {
                f.write_str(": ")?;
            }
            f.write_str(msg)?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our message chain.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension methods mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily-built context message to the error case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a literal, format string, or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+).into())
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            ))
            .into());
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing").unwrap_err();
        assert_eq!(e.root_message(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "flag was {}", fail);
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        let e = inner(true).unwrap_err();
        assert_eq!(format!("{e}"), "flag was true");
        let e = anyhow!("x = {}", 5);
        assert_eq!(format!("{e}"), "x = 5");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
    }

    #[test]
    fn bail_returns_early() {
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope 1");
    }

    #[test]
    fn error_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<Error>();
    }
}
