//! Steady-state allocation discipline: after one warm-up call per shape,
//! the codec hot path (`compress_into` / `decompress_into` with a reused
//! scratch arena, payload, and output tensor) performs **zero heap
//! allocations** — the acceptance criterion of the fused-codec perf
//! refactor.
//!
//! Verified with a counting global allocator, which is why this test lives
//! alone in its own integration-test binary: the count is process-global,
//! and a lone `#[test]` keeps harness noise out of the measured windows.
//! To tolerate any residual runtime allocation (e.g. lazy stdio), each
//! codec measures several windows and asserts the *minimum* is zero — a
//! per-call allocation would show up in every window.

use slfac::codec::{self, CodecParams, CodecScratch, Payload};
use slfac::dct::Dct2d;
use slfac::rng::{stream, Pcg32};
use slfac::tensor::Tensor;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: defers all allocation to `System`; only adds a relaxed counter.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations observed across `f()`.
fn count_allocs(mut f: impl FnMut()) -> u64 {
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    f();
    ALLOC_CALLS.load(Ordering::Relaxed) - before
}

#[test]
fn steady_state_compress_decompress_is_allocation_free() {
    // the paper codec at MNIST scale (14×14, fused kernel + planned
    // zig-zag), plus the uniform baselines — all scratch-arena paths
    // (easyquant joined once its fit gained the recycled outlier buffer;
    // the literature-cluster codecs were written against this bar from the
    // start — no sorts, scratch-staged folds, cached NSC-SL bases)
    for (name, shape) in [
        ("slfac", [4usize, 8, 14, 14]),
        ("slfac", [2, 4, 16, 16]),
        ("uniform", [4, 8, 14, 14]),
        ("easyquant", [4, 8, 14, 14]),
        ("identity", [2, 4, 8, 8]),
        ("sl-acc", [4, 8, 14, 14]),
        ("featurewise", [4, 8, 14, 14]),
        ("mask-topk", [4, 8, 14, 14]),
        ("nsc-sl", [4, 8, 14, 14]),
    ] {
        let params = CodecParams::default();
        let c = codec::by_name(name, &params).unwrap();
        let x = if c.frequency_domain() {
            Dct2d::forward_tensor(&codec::smooth_activations(&shape, 0xA110C))
        } else {
            codec::smooth_activations(&shape, 0xA110C)
        };
        let mut rng = Pcg32::derived(1, stream::CODEC, 0);
        let mut scratch = CodecScratch::new();
        let mut payload = Payload::empty();
        let mut out = Tensor::zeros(&[1]);

        let mut cycle = || {
            c.compress_into(&x, &mut rng, &mut scratch, &mut payload).unwrap();
            c.decompress_into(&payload, &mut scratch, &mut out).unwrap();
        };
        // warm-up: builds plans, sizes every buffer to this shape
        for _ in 0..3 {
            cycle();
        }
        // measure several windows; a true per-call allocation would appear
        // in all of them
        let min_allocs = (0..5)
            .map(|_| count_allocs(|| for _ in 0..10 { cycle() }))
            .min()
            .unwrap();
        assert_eq!(
            min_allocs, 0,
            "{name} {shape:?}: steady-state hot path allocated"
        );
        // the payload produced by the allocation-free path is still the
        // canonical one
        let want = c.compress_with_rng(&x, &mut Pcg32::derived(1, stream::CODEC, 0)).unwrap();
        // (slfac/uniform/identity ignore the rng, so stream position is moot)
        assert_eq!(payload.to_bytes(), want.to_bytes(), "{name}");
    }
}
