//! Cross-language golden tests: the Rust frequency stack (DCT, zig-zag,
//! AFD) must agree bit-for-bit in semantics with the Python/Pallas side.
//! Vectors are emitted by `python/compile/aot.py` (`make artifacts`).
//!
//! Skipped (with a notice) when `artifacts/golden/golden.json` is absent.

use slfac::dct::Dct2d;
use slfac::freq::{afd_channel, zigzag};
use slfac::json::Json;
use slfac::tensor::Tensor;

fn load_golden() -> Option<Json> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/golden/golden.json");
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            eprintln!("SKIP: {path} missing — run `make artifacts`");
            return None;
        }
    };
    Some(Json::parse(&text).expect("golden.json must parse"))
}

#[test]
fn rust_dct_matches_pallas_kernel() {
    let Some(g) = load_golden() else { return };
    let cases = g.get("dct_cases").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let shape: Vec<usize> = case
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        let input: Vec<f32> = case
            .get("input")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let expect: Vec<f32> = case
            .get("dct")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let x = Tensor::new(&shape, input);
        let got = Dct2d::forward_tensor(&x);
        let want = Tensor::new(&shape, expect);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 2e-4, "shape {shape:?}: max diff {diff}");
        // and python's own roundtrip error was tiny
        let rt = case
            .get("idct_roundtrip_max_err")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(rt < 1e-3, "python roundtrip err {rt}");
    }
}

#[test]
fn rust_zigzag_matches_python() {
    let Some(g) = load_golden() else { return };
    let zz_obj = g.get("zigzag").unwrap().as_obj().unwrap();
    assert!(!zz_obj.is_empty());
    for (key, order) in zz_obj {
        let (m, n) = key.split_once('x').unwrap();
        let (m, n): (usize, usize) = (m.parse().unwrap(), n.parse().unwrap());
        let want: Vec<u32> = order
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap() as u32)
            .collect();
        let got = zigzag(m, n);
        assert_eq!(got.scan, want, "zigzag {m}x{n}");
    }
}

#[test]
fn rust_afd_split_matches_python() {
    let Some(g) = load_golden() else { return };
    let cases = g.get("afd_cases").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let m = case.get("m").unwrap().as_usize().unwrap();
        let n = case.get("n").unwrap().as_usize().unwrap();
        let plane: Vec<f32> = case
            .get("plane")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let theta = case.get("theta").unwrap().as_f64().unwrap();
        let want_k = case.get("k_star").unwrap().as_usize().unwrap();
        let zz = zigzag(m, n);
        let split = afd_channel(&zz, &plane, theta);
        assert_eq!(split.k, want_k, "{m}x{n} theta={theta}");
    }
}
