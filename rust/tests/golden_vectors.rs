//! Cross-language golden tests: the Rust frequency stack (DCT, zig-zag,
//! AFD) must agree bit-for-bit in semantics with the Python/Pallas side.
//! Vectors are emitted by `python/compile/aot.py` (`make artifacts`).
//!
//! Skipped (with a notice) when `artifacts/golden/golden.json` is absent.
//!
//! Additionally: **wire-format golden vectors** pin the exact serialized
//! bytes of one payload per registered codec
//! (`tests/golden/codec_wire.json`). Blessing is **explicit only**: run
//! with `SLFAC_BLESS=1` to (re)write the file — see
//! [`codec_wire_bytes_match_golden_vectors`]. A missing golden file is a
//! loud SKIP locally and a hard failure under CI, so refactors of the
//! codec or threading layers cannot silently re-baseline what goes on
//! the wire.

use slfac::codec::{self, CodecParams, MaskTopKCodec, MaskTopKConfig, Payload};
use slfac::dct::Dct2d;
use slfac::freq::{afd_channel, zigzag};
use slfac::json::Json;
use slfac::tensor::Tensor;
use std::collections::BTreeMap;

fn load_golden() -> Option<Json> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/golden/golden.json");
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            eprintln!("SKIP: {path} missing — run `make artifacts`");
            return None;
        }
    };
    Some(Json::parse(&text).expect("golden.json must parse"))
}

#[test]
fn rust_dct_matches_pallas_kernel() {
    let Some(g) = load_golden() else { return };
    let cases = g.get("dct_cases").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let shape: Vec<usize> = case
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        let input: Vec<f32> = case
            .get("input")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let expect: Vec<f32> = case
            .get("dct")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let x = Tensor::new(&shape, input);
        let got = Dct2d::forward_tensor(&x);
        let want = Tensor::new(&shape, expect);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 2e-4, "shape {shape:?}: max diff {diff}");
        // and python's own roundtrip error was tiny
        let rt = case
            .get("idct_roundtrip_max_err")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(rt < 1e-3, "python roundtrip err {rt}");
    }
}

#[test]
fn rust_zigzag_matches_python() {
    let Some(g) = load_golden() else { return };
    let zz_obj = g.get("zigzag").unwrap().as_obj().unwrap();
    assert!(!zz_obj.is_empty());
    for (key, order) in zz_obj {
        let (m, n) = key.split_once('x').unwrap();
        let (m, n): (usize, usize) = (m.parse().unwrap(), n.parse().unwrap());
        let want: Vec<u32> = order
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap() as u32)
            .collect();
        let got = zigzag(m, n);
        assert_eq!(got.scan, want, "zigzag {m}x{n}");
    }
}

// --- codec wire-format golden vectors -----------------------------------

/// Deterministic input for the wire vectors (fixed shape + seed).
const WIRE_SHAPE: [usize; 4] = [1, 3, 6, 6];
const WIRE_SEED: u64 = 0x601D;

fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Serialized wire bytes for one payload per registered codec, as
/// `name -> hex` (BTreeMap: stable file ordering).
fn current_wire_vectors() -> BTreeMap<String, String> {
    let params = CodecParams::default();
    let x = codec::smooth_activations(&WIRE_SHAPE, WIRE_SEED);
    let coeffs = Dct2d::forward_tensor(&x);
    codec::ALL_CODECS
        .iter()
        .map(|name| {
            // fresh codec per case: randomized codecs start from their
            // configured seed, so bytes are reproducible
            let c = codec::by_name(name, &params).unwrap();
            let input = if c.frequency_domain() { &coeffs } else { &x };
            let p = c.compress(input).unwrap();
            // structural invariants hold for every codec, golden or not
            assert_eq!(p.wire_bytes(), p.to_bytes().len(), "{name}");
            let back = Payload::from_bytes(&p.to_bytes()).unwrap();
            assert_eq!(back.to_bytes(), p.to_bytes(), "{name}");
            (name.to_string(), hex(&p.to_bytes()))
        })
        .collect()
}

fn golden_wire_path() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/codec_wire.json").to_string()
}

#[test]
fn codec_wire_bytes_match_golden_vectors() {
    let current = current_wire_vectors();
    let path = golden_wire_path();
    // Blessing is explicit only: a test run must never re-baseline the wire
    // format as a side effect. Missing golden + CI => fail hard (the repo
    // should ship the file, or CI must run the dedicated bless step first);
    // missing golden locally => loud SKIP so `cargo test` stays green on a
    // fresh checkout without silently pinning unreviewed bytes.
    let bless = std::env::var("SLFAC_BLESS").is_ok();
    if !bless && !std::path::Path::new(&path).exists() {
        if std::env::var("CI").is_ok() {
            panic!(
                "{path} missing under CI — run \
                 `SLFAC_BLESS=1 cargo test --test golden_vectors codec_wire` \
                 and commit the blessed file"
            );
        }
        eprintln!(
            "SKIP: {path} missing — bless with \
             `SLFAC_BLESS=1 cargo test --test golden_vectors codec_wire` \
             and commit the file to lock the wire format"
        );
        return;
    }
    if bless {
        // explicit re-bless: write the vectors; commit the file to lock
        // the wire format
        let mut m = BTreeMap::new();
        for (k, v) in &current {
            m.insert(k.clone(), Json::Str(v.clone()));
        }
        let mut root = BTreeMap::new();
        root.insert(
            "shape".to_string(),
            Json::Arr(WIRE_SHAPE.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        root.insert("seed".to_string(), Json::Num(WIRE_SEED as f64));
        root.insert("payloads".to_string(), Json::Obj(m));
        std::fs::create_dir_all(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
            .unwrap();
        std::fs::write(&path, Json::Obj(root).to_string()).unwrap();
        // the blessed file must parse and reproduce the vectors we hold
        let reread = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let payloads = reread.get("payloads").unwrap().as_obj().unwrap();
        assert_eq!(payloads.len(), current.len());
        for (name, hexv) in &current {
            assert_eq!(payloads.get(name).unwrap().as_str().unwrap(), hexv, "{name}");
        }
        eprintln!("BLESSED wire golden vectors -> {path} (commit this file to lock the format)");
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let g = Json::parse(&text).expect("codec_wire.json must parse");
    let payloads = g.get("payloads").unwrap().as_obj().unwrap();
    // every registered codec has a pinned payload, and vice versa
    let golden_names: Vec<&str> = payloads.keys().map(|s| s.as_str()).collect();
    let current_names: Vec<&str> = current.keys().map(|s| s.as_str()).collect();
    assert_eq!(
        golden_names, current_names,
        "codec set changed — rerun with SLFAC_BLESS=1 and review the diff"
    );
    for (name, want) in payloads {
        let got = &current[name];
        assert_eq!(
            got,
            want.as_str().unwrap(),
            "codec '{name}' changed its wire bytes — if intentional, \
             re-bless with SLFAC_BLESS=1 and bump the payload version"
        );
    }
}

/// Mask-encoded top-k bit-layout oracle: the wire bytes of a
/// hand-computable payload, derived **independently** of the encoder.
/// This is the human-readable counterpart of the hex in
/// `codec_wire.json` — if either this test or the golden hex moves, the
/// mask-topk format changed.
///
/// Layout per sample: `f32 γ | f32 min | f32 max | ⌈P/8⌉ bitmap
/// (LSB-first kept flags) | ⌈k·bits/8⌉ packed levels (MSB-first,
/// ascending element index)`.
#[test]
fn masktopk_bit_layout_oracle() {
    use slfac::codec::ActivationCodec;
    // P = 8 elements, keep 0.5 -> k = 4; the four nonzeros are kept and
    // the dropped elements are zero, so γ = √(total/kept energy) = 1.0
    // exactly. min = -7, max = 8 -> 4-bit step = (8 - -7)/15 = 1.0, and
    // every kept value sits exactly on the lattice.
    let x = Tensor::new(&[1, 1, 2, 4], vec![8.0, 0.0, 0.0, 6.0, -7.0, 0.0, 2.0, 0.0]);
    let c = MaskTopKCodec::new(MaskTopKConfig {
        keep_fraction: 0.5,
        bits: 4,
    });
    let p = c.compress(&x).unwrap();
    let mut want = Vec::new();
    want.extend_from_slice(&1.0f32.to_le_bytes()); // γ
    want.extend_from_slice(&(-7.0f32).to_le_bytes()); // min
    want.extend_from_slice(&8.0f32.to_le_bytes()); // max
    // kept indices {0, 3, 4, 6} -> bits 0,3,4,6 set
    want.push(0b0101_1001);
    // levels round((v - min)/step): 8->15, 6->13, -7->0, 2->9, packed
    // MSB-first in index order: (15,13) (0,9)
    want.extend_from_slice(&[0xFD, 0x09]);
    assert_eq!(p.body, want, "mask-topk wire layout changed");
    // lattice-exact input reconstructs bit-exactly
    assert_eq!(c.decompress(&p).unwrap().data(), x.data());
}

#[test]
fn rust_afd_split_matches_python() {
    let Some(g) = load_golden() else { return };
    let cases = g.get("afd_cases").unwrap().as_arr().unwrap();
    assert!(!cases.is_empty());
    for case in cases {
        let m = case.get("m").unwrap().as_usize().unwrap();
        let n = case.get("n").unwrap().as_usize().unwrap();
        let plane: Vec<f32> = case
            .get("plane")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let theta = case.get("theta").unwrap().as_f64().unwrap();
        let want_k = case.get("k_star").unwrap().as_usize().unwrap();
        let zz = zigzag(m, n);
        let split = afd_channel(&zz, &plane, theta);
        assert_eq!(split.k, want_k, "{m}x{n} theta={theta}");
    }
}
