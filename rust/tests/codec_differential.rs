//! Differential tests for the fused codec hot path (the perf refactor's
//! safety net): the fused single-pass kernels, the word-level bit packing,
//! and the scratch-arena buffer reuse must be **invisible on the wire** —
//! bit-identical payload bytes and bit-identical decoded tensors vs the
//! multi-pass reference kernels and the allocating API, over randomized
//! shapes, seeds, θ, and bit bounds.

// `ActivationCodec` must be in scope for the trait-method calls on the
// concrete `SlFacCodec` values below (trait objects wouldn't need it).
use slfac::codec::{
    self, ActivationCodec, CodecParams, CodecScratch, Payload, SlFacCodec, SlFacConfig,
};
use slfac::dct::Dct2d;
use slfac::quant::AllocationConfig;
use slfac::rng::{stream, Pcg32};
use slfac::tensor::Tensor;
use slfac::testing::prop;

/// The tentpole acceptance property: fast and reference SL-FAC kernels
/// produce identical wire bytes and identical decoded tensors for
/// randomized shapes, input statistics, seeds, θ, and FQC bit bounds.
#[test]
fn fast_kernels_bit_identical_to_reference() {
    prop("slfac fast == reference", 120, |g| {
        let shape = g.bchw_shape();
        let theta = *g.choose(&[0.5f64, 0.7, 0.8, 0.9, 0.95, 0.99, 1.0]);
        let b_min = g.usize_in(1, 6) as u32;
        let b_max = b_min + g.usize_in(0, 16 - b_min as usize) as u32;
        // mix of smooth coefficient planes, raw noise, and spiky data
        let x = match g.usize_in(0, 2) {
            0 => Dct2d::forward_tensor(&g.tensor(&shape, 2.0)),
            1 => g.tensor(&shape, *g.choose(&[0.1f32, 1.0, 10.0])),
            _ => {
                let n = shape.iter().product();
                Tensor::new(&shape, g.spiky_vec(n))
            }
        };
        let alloc = AllocationConfig { b_min, b_max };
        let fast = SlFacCodec::new(SlFacConfig {
            theta,
            alloc,
            fast_path: true,
        });
        let reference = SlFacCodec::new(SlFacConfig {
            theta,
            alloc,
            fast_path: false,
        });
        let pf = fast.compress(&x).unwrap();
        let pr = reference.compress(&x).unwrap();
        assert_eq!(
            pf.to_bytes(),
            pr.to_bytes(),
            "wire bytes diverged: shape {shape:?} θ={theta} bits=[{b_min},{b_max}]"
        );
        let df = fast.decompress(&pf).unwrap();
        let dr = reference.decompress(&pr).unwrap();
        assert_eq!(df.shape(), dr.shape());
        // bitwise, not approximate: compare raw f32 bit patterns
        let bits = |t: &Tensor| -> Vec<u32> { t.data().iter().map(|v| v.to_bits()).collect() };
        assert_eq!(bits(&df), bits(&dr), "decoded tensors diverged");
    });
}

/// Degenerate inputs exercise every edge branch of the fused kernel: the
/// all-zero channel (k* = 1 path), constant channels (degenerate quantizer
/// ranges), negative zeros (sign-sensitive min/max bytes), single-element
/// planes.
#[test]
fn fast_kernels_bit_identical_on_degenerate_inputs() {
    let mk = |fast: bool| {
        SlFacCodec::new(SlFacConfig {
            fast_path: fast,
            ..Default::default()
        })
    };
    let (fast, reference) = (mk(true), mk(false));
    let cases: Vec<Tensor> = vec![
        Tensor::zeros(&[1, 2, 5, 5]),
        Tensor::full(&[2, 1, 4, 4], 3.25),
        Tensor::full(&[1, 1, 1, 1], -7.5),
        Tensor::full(&[1, 3, 6, 6], -0.0),
        {
            let mut t = Tensor::zeros(&[1, 1, 4, 4]);
            t.data_mut()[0] = -0.0; // negative zero at DC
            t.data_mut()[15] = 1e-20; // tiny tail energy
            t
        },
        {
            let mut t = Tensor::full(&[1, 1, 3, 3], 1.0);
            t.data_mut()[4] = f32::MAX / 4.0; // huge mid coefficient
            t
        },
    ];
    for (i, x) in cases.iter().enumerate() {
        let pf = fast.compress(x).unwrap();
        let pr = reference.compress(x).unwrap();
        assert_eq!(pf.to_bytes(), pr.to_bytes(), "case {i}");
        assert_eq!(
            fast.decompress(&pf).unwrap().data(),
            reference.decompress(&pr).unwrap().data(),
            "case {i}"
        );
    }
}

/// Every registered codec: the scratch-arena API (`compress_into` /
/// `decompress_into`, with one arena reused across calls and shapes) must
/// produce byte-identical payloads and bit-identical decodes vs the
/// allocating API at the same RNG stream position.
#[test]
fn scratch_api_matches_allocating_api_for_every_codec() {
    prop("scratch == allocating", 60, |g| {
        let params = CodecParams::default();
        let name = *g.choose(codec::ALL_CODECS);
        let c = codec::by_name(name, &params).unwrap();
        let shape = g.bchw_shape();
        let x = if c.frequency_domain() {
            Dct2d::forward_tensor(&g.tensor(&shape, 1.5))
        } else {
            g.tensor(&shape, 1.5)
        };
        // same derived stream for both paths (randomized codecs must draw
        // identically)
        let seed = 0xD1FF ^ g.case as u64;
        let mut rng_a = Pcg32::derived(seed, stream::CODEC, 0);
        let mut rng_b = Pcg32::derived(seed, stream::CODEC, 0);

        let mut scratch = CodecScratch::new();
        let mut got = Payload::empty();
        got.body = scratch.take_body();
        c.compress_into(&x, &mut rng_a, &mut scratch, &mut got).unwrap();
        let want = c.compress_with_rng(&x, &mut rng_b).unwrap();
        assert_eq!(got.to_bytes(), want.to_bytes(), "{name} {shape:?}");

        let mut out = Tensor::zeros(&[1]);
        c.decompress_into(&got, &mut scratch, &mut out).unwrap();
        let reference = c.decompress(&want).unwrap();
        assert_eq!(out.shape(), reference.shape(), "{name}");
        let bits = |t: &Tensor| -> Vec<u32> { t.data().iter().map(|v| v.to_bits()).collect() };
        assert_eq!(bits(&out), bits(&reference), "{name} decode");

        // second use of the same arena + payload + output tensor (dirty
        // buffers, possibly different shape) must be just as transparent
        let shape2 = g.bchw_shape();
        let x2 = if c.frequency_domain() {
            Dct2d::forward_tensor(&g.tensor(&shape2, 0.7))
        } else {
            g.tensor(&shape2, 0.7)
        };
        c.compress_into(&x2, &mut rng_a, &mut scratch, &mut got).unwrap();
        let want2 = c.compress_with_rng(&x2, &mut rng_b).unwrap();
        assert_eq!(got.to_bytes(), want2.to_bytes(), "{name} reuse {shape2:?}");
        c.decompress_into(&got, &mut scratch, &mut out).unwrap();
        assert_eq!(
            bits(&out),
            bits(&c.decompress(&want2).unwrap()),
            "{name} reuse decode"
        );
    });
}

/// The `codec_fast_path` toggle flows through the factory: both modes
/// build, and their products are interchangeable on the wire.
#[test]
fn factory_fast_path_toggle_is_wire_transparent() {
    let fast_params = CodecParams::default();
    let ref_params = CodecParams {
        fast_path: false,
        ..Default::default()
    };
    let x = Dct2d::forward_tensor(&codec::smooth_activations(&[2, 4, 14, 14], 99));
    // sl-acc is spatial but carries the same fused/reference dual kernel;
    // coefficient planes are as good an input as any for bit-identity
    for name in &["slfac", "afd-uniform", "sl-acc"] {
        let fast = codec::by_name(name, &fast_params).unwrap();
        let reference = codec::by_name(name, &ref_params).unwrap();
        let pf = fast.compress(&x).unwrap();
        let pr = reference.compress(&x).unwrap();
        assert_eq!(pf.to_bytes(), pr.to_bytes(), "{name}");
        // cross-decode: reference decodes the fast payload and vice versa
        assert_eq!(
            reference.decompress(&pf).unwrap().data(),
            fast.decompress(&pr).unwrap().data(),
            "{name}"
        );
    }
}
