//! Steady-state allocation discipline for the compute spine: one full
//! training round — batch loading, client forward (+DCT), codec
//! compress/decompress both directions, inverse DCT, server step, client
//! backward, FedAvg, and evaluation — driven through the device-resident
//! fast path exactly as the trainer drives it, performs **zero heap
//! allocations** once warm. This is the executor-side counterpart of
//! `tests/codec_zero_alloc.rs` (PR 4 pinned the codec half; this pins the
//! model-compute half plus their composition).
//!
//! Scope note: as of the fleet-scale PR the transport bookkeeping around
//! a round (event queue, cohort grouping arenas, `UplinkMsg` staging
//! vectors) is also allocation-free once warm — the scheduler owns
//! round-persistent scratch and `RoundOps::fanout` fills a caller-owned
//! buffer instead of returning a fresh `Vec`. That half is pinned by
//! [`transport_round_is_allocation_free`] below, driving both schedulers
//! over [`FleetOps`] with cohorts off and on. Still exempt by design:
//! the shared-pipe modes (`SharedUplink`'s per-flow state grows with
//! concurrent flows) and the reference (non-resident) compute path's
//! per-step parameter clones — neither is on the fleet hot path.
//!
//! Verified with a counting global allocator, which is why this test lives
//! alone in its own integration-test binary. Each window measures several
//! runs and asserts the *minimum* is zero — a per-step allocation would
//! show up in every window.

use slfac::codec::{self, CodecParams, CodecScratch, Payload};
use slfac::data::{synthetic, BatchLoader};
use slfac::rng::{stream, Pcg32};
use slfac::runtime::{write_sim_manifest, ExecutorHandle, ResidentSession, SimManifestSpec};
use slfac::tensor::Tensor;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: defers all allocation to `System`; only adds a relaxed counter.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations observed across `f()`.
fn count_allocs(mut f: impl FnMut()) -> u64 {
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    f();
    ALLOC_CALLS.load(Ordering::Relaxed) - before
}

const BATCH: usize = 4;
const DEVICES: usize = 3;
const STEPS: usize = 2;

/// Everything one device owns on the fast path (mirrors `DeviceCtx`).
struct Dev {
    loader: BatchLoader,
    codec_rng: Pcg32,
    scratch: CodecScratch,
    x: Vec<f32>,
    y: Vec<i32>,
    wire: Tensor,
    decode: Tensor,
    spatial: Tensor,
}

/// One full training round through the resident session, mirroring the
/// trainer's fast-path phase bodies per device per step.
fn round(
    res: &ResidentSession,
    codec: &dyn codec::ActivationCodec,
    devs: &mut [Dev],
    train: &slfac::data::Dataset,
    test: &slfac::data::Dataset,
    weights: &[f64],
) {
    let freq = codec.frequency_domain();
    for d in 0..devs.len() {
        res.load_client_from_agg(d).unwrap();
    }
    for _step in 0..STEPS {
        for (id, dev) in devs.iter_mut().enumerate() {
            // fan-out: batch + forward + encode
            dev.loader.next_batch_into(train, &mut dev.x, &mut dev.y);
            res.client_fwd(id, &dev.x, freq, &mut dev.wire).unwrap();
            let mut up = Payload::empty();
            up.body = dev.scratch.take_body();
            codec
                .compress_into(&dev.wire, &mut dev.codec_rng, &mut dev.scratch, &mut up)
                .unwrap();

            // server: decode + idct + step + gradient encode
            codec.decompress_into(&up, &mut dev.scratch, &mut dev.decode).unwrap();
            dev.scratch.recycle_body(std::mem::take(&mut up.body));
            let (loss, _correct) = if freq {
                res.idct(id, &dev.decode, &mut dev.spatial).unwrap();
                res.server_step(&dev.spatial, &dev.y, 0.05, true, &mut dev.wire)
                    .unwrap()
            } else {
                res.server_step(&dev.decode, &dev.y, 0.05, false, &mut dev.wire)
                    .unwrap()
            };
            assert!(loss.is_finite());
            let mut down = Payload::empty();
            down.body = dev.scratch.take_body();
            codec
                .compress_into(&dev.wire, &mut dev.codec_rng, &mut dev.scratch, &mut down)
                .unwrap();

            // fan-in: decode + idct + backward
            codec
                .decompress_into(&down, &mut dev.scratch, &mut dev.decode)
                .unwrap();
            dev.scratch.recycle_body(std::mem::take(&mut down.body));
            if freq {
                res.idct(id, &dev.decode, &mut dev.spatial).unwrap();
                res.client_step(id, &dev.x, &dev.spatial, 0.05).unwrap();
            } else {
                res.client_step(id, &dev.x, &dev.decode, 0.05).unwrap();
            }
        }
    }
    res.fedavg(weights).unwrap();
    for i in 0..test.len() / BATCH {
        let (loss, _) = res.eval_batch(test, i * BATCH, BATCH).unwrap();
        assert!(loss.is_finite());
    }
}

#[test]
fn steady_state_training_round_is_allocation_free() {
    let dir = format!(
        "{}/slfac_compute_alloc_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: BATCH,
            act_channels: 2,
            act_hw: 8,
        }],
    )
    .unwrap();
    let exec = ExecutorHandle::spawn_sim(&dir, &["mnist".into()]).unwrap();
    let (train, test) = synthetic::mnist_like(&synthetic::DatasetSpec {
        train_samples: 24 * DEVICES,
        test_samples: 2 * BATCH,
        noise: 0.2,
        seed: 9,
    });
    let weights: Vec<f64> = (1..=DEVICES).map(|d| d as f64).collect();

    // the paper codec (frequency domain: resident forward-DCT + idct on
    // the hot path) and identity (spatial) both must hold the guarantee
    for name in ["slfac", "identity"] {
        let res = exec
            .open_resident("mnist", DEVICES)
            .unwrap()
            .expect("sim backend supports resident state");
        let codec = codec::by_name(name, &CodecParams::default()).unwrap();
        let mut devs: Vec<Dev> = (0..DEVICES)
            .map(|d| Dev {
                loader: BatchLoader::new(
                    (d * 24..(d + 1) * 24).collect(),
                    BATCH,
                    d as u64,
                ),
                codec_rng: Pcg32::derived(1, stream::CODEC, d as u64),
                scratch: CodecScratch::new(),
                x: Vec::new(),
                y: Vec::new(),
                wire: Tensor::zeros(&[1]),
                decode: Tensor::zeros(&[1]),
                spatial: Tensor::zeros(&[1]),
            })
            .collect();

        // warm-up: size every slot buffer, build plans, fill body pools
        for _ in 0..3 {
            round(&res, codec.as_ref(), &mut devs, &train, &test, &weights);
        }
        // measure several windows; a true per-round allocation would
        // appear in all of them
        let min_allocs = (0..5)
            .map(|_| {
                count_allocs(|| {
                    for _ in 0..3 {
                        round(&res, codec.as_ref(), &mut devs, &train, &test, &weights);
                    }
                })
            })
            .min()
            .unwrap();
        assert_eq!(
            min_allocs, 0,
            "{name}: steady-state training round allocated"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The transport half of the discipline: a full scheduler round —
/// fan-out staging, event-queue (or cohort-fold) control flow, server
/// contention accounting, fan-in — performs zero heap allocations once
/// the round-persistent scratch is warm. Driven over [`FleetOps`]
/// (pure-bookkeeping device work) so only the transport layer is on the
/// clock, across both schedulers with the cohort path off and on.
#[test]
fn transport_round_is_allocation_free() {
    use slfac::transport::fleet::{FleetCohort, FleetOps};
    use slfac::transport::{
        AsyncEventScheduler, RoundScheduler, StragglerPolicy, SyncEventScheduler,
    };

    let profiles = vec![
        FleetCohort::default(),
        FleetCohort {
            compute_s: 0.006,
            uplink_cost_s: 0.045,
            downlink_s: 0.020,
            uplink_bytes: 12_000,
            downlink_bytes: 6_000,
        },
    ];
    let schedulers: [(&str, Box<dyn RoundScheduler>); 2] = [
        ("sync", Box::new(SyncEventScheduler::new())),
        (
            "async/wait-all",
            Box::new(AsyncEventScheduler::new(StragglerPolicy::WaitAll)),
        ),
    ];
    for (label, sched) in &schedulers {
        for cohorts in [0usize, 4] {
            let mut ops = FleetOps::new(64, 3, profiles.clone());
            ops.set_cohorts(cohorts);
            ops.set_server_service_s(5e-4);
            // fault injection disarmed — the default. The schedulers now
            // probe `fault_plan()` every round before picking a path;
            // with inert knobs that probe (and the fault scratch sitting
            // idle in the scheduler) must add zero per-message work.
            ops.set_fault(None);
            // warm-up: grow the scheduler's round-persistent scratch and
            // the fan-out staging buffer to their steady-state sizes
            for _ in 0..3 {
                sched.run_round(&mut ops).unwrap();
            }
            let min_allocs = (0..5)
                .map(|_| {
                    count_allocs(|| {
                        for _ in 0..3 {
                            sched.run_round(&mut ops).unwrap();
                        }
                    })
                })
                .min()
                .unwrap();
            assert_eq!(
                min_allocs, 0,
                "{label} cohorts={cohorts}: transport round allocated"
            );
        }
    }
}
