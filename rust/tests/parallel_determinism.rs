//! Differential determinism: the thread-parallel round engine must be
//! **bit-transparent**. For multiple seeds, both sync modes, and both a
//! deterministic frequency codec (slfac) and a randomized spatial codec
//! (tk-sl), a run with `workers = 4` (and `workers = 0` = auto) must
//! reproduce the `workers = 1` run exactly: `TrainingHistory`, `CommStats`,
//! and final client/server parameters, all compared bit-for-bit.
//!
//! The same contract covers the **async round scheduler**: for multiple
//! seeds, codecs, and straggler policies over a heterogeneous `wifi/lte`
//! fleet, simulated-time event ordering — not thread ordering — is the
//! source of truth, so every worker count reproduces the `workers = 1`
//! run exactly. Additionally, async with homogeneous profiles and the
//! `wait-all` policy must match sync-mode byte totals (and parameters)
//! exactly for fixed-rate codecs, and round-1 uplink totals for the
//! content-adaptive ones.
//!
//! Runs on the sim executor backend (pure Rust, manifest only), so this
//! test needs no XLA runtime and no `make artifacts` — it always runs.

use slfac::config::{ExperimentConfig, SyncMode};
use slfac::coordinator::{TrainOutcome, Trainer};
use slfac::net::CommStats;
use slfac::runtime::{write_sim_manifest, ExecutorHandle, HostTensor, SimManifestSpec};
use slfac::transport::{ClientSampling, SchedulerKind, StragglerPolicy, UplinkMode};

const BATCH: usize = 8;

fn sim_dir(label: &str) -> String {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = format!(
        "{}/slfac_pardet_{label}_{}_{}",
        std::env::temp_dir().display(),
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: BATCH,
            act_channels: 2,
            act_hw: 4,
        }],
    )
    .unwrap();
    dir
}

fn cfg(dir: &str, codec: &str, sync: SyncMode, seed: u64, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("pardet_{codec}_{seed}_{workers}"),
        codec: codec.into(),
        devices: 4,
        workers,
        sync,
        rounds: 2,
        batches_per_round: 2,
        batch_size: BATCH,
        train_samples: 160,
        test_samples: 2 * BATCH,
        seed,
        artifacts_dir: dir.into(),
        ..Default::default()
    }
}

struct RunResult {
    outcome: TrainOutcome,
    client: Vec<HostTensor>,
    server: Vec<HostTensor>,
}

fn run(cfg: ExperimentConfig) -> RunResult {
    let exec = ExecutorHandle::spawn_sim(&cfg.artifacts_dir, &["mnist".into()])
        .expect("sim executor");
    let mut trainer = Trainer::new(cfg, exec).expect("trainer");
    let outcome = trainer.run().expect("run");
    RunResult {
        outcome,
        client: trainer.client_params(),
        server: trainer.server_params(),
    }
}

fn param_bits(params: &[HostTensor]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert!(
        a.outcome.history.bit_eq(&b.outcome.history),
        "{label}: TrainingHistory diverged"
    );
    assert!(
        a.outcome.comm.bit_eq(&b.outcome.comm),
        "{label}: CommStats diverged: {:?} vs {:?}",
        a.outcome.comm,
        b.outcome.comm
    );
    assert_eq!(
        param_bits(&a.client),
        param_bits(&b.client),
        "{label}: client params diverged"
    );
    assert_eq!(
        param_bits(&a.server),
        param_bits(&b.server),
        "{label}: server params diverged"
    );
}

#[test]
fn parallel_workers_match_sequential_bitwise() {
    let dir = sim_dir("main");
    for &seed in &[7u64, 1234] {
        for (sync, sync_name) in [
            (SyncMode::ParallelFedAvg, "parallel"),
            (SyncMode::Sequential, "sequential"),
        ] {
            for codec in ["slfac", "tk-sl"] {
                let reference = run(cfg(&dir, codec, sync, seed, 1));
                for workers in [4usize, 0] {
                    let got = run(cfg(&dir, codec, sync, seed, workers));
                    assert_bit_identical(
                        &reference,
                        &got,
                        &format!("seed={seed} sync={sync_name} codec={codec} workers={workers}"),
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repeated_parallel_runs_are_self_consistent() {
    // same seed + same workers, run twice: scheduling noise between the
    // two runs must not leak into any result
    let dir = sim_dir("repeat");
    let a = run(cfg(&dir, "tk-sl", SyncMode::ParallelFedAvg, 42, 4));
    let b = run(cfg(&dir, "tk-sl", SyncMode::ParallelFedAvg, 42, 4));
    assert_bit_identical(&a, &b, "repeat tk-sl workers=4");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fast_path_and_scratch_arenas_are_bit_transparent() {
    // Two pins in one matrix. (1) Per-worker `CodecScratch` arenas: each
    // device's arena is reused dirty across batches and rounds, and the
    // shard→worker assignment changes with the worker count — results must
    // not. (2) The fused codec kernels (`codec_fast_path = true`, default)
    // vs the multi-pass reference kernels: identical wire bytes means
    // identical byte accounting, identical link timing, and identical
    // training trajectories, end to end.
    let dir = sim_dir("fastpath");
    for &seed in &[7u64, 1234] {
        let mut ref_cfg = cfg(&dir, "slfac", SyncMode::ParallelFedAvg, seed, 1);
        ref_cfg.codec_params.fast_path = false;
        let reference = run(ref_cfg);
        for workers in [1usize, 4] {
            for fast in [true, false] {
                let mut c = cfg(&dir, "slfac", SyncMode::ParallelFedAvg, seed, workers);
                c.name = format!("pardet_fastpath_{seed}_{workers}_{fast}");
                c.codec_params.fast_path = fast;
                let got = run(c);
                assert_bit_identical(
                    &reference,
                    &got,
                    &format!("seed={seed} workers={workers} fast_path={fast}"),
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compute_fast_path_is_bit_transparent_across_workers() {
    // The compute-backend matrix: `compute_fast_path` (device-resident
    // blocked kernels vs the artifact execute path with reference
    // kernels) × workers 1/4 must all reproduce the reference run
    // (compute_fast_path = false, workers = 1) bit-for-bit — histories,
    // comm stats, and final parameters. Device-resident state means the
    // weights never leave the executor on the fast path; this pins that
    // the relocation is purely mechanical.
    let dir = sim_dir("computefast");
    for &seed in &[7u64, 1234] {
        for codec in ["slfac", "tk-sl"] {
            let mut ref_cfg = cfg(&dir, codec, SyncMode::ParallelFedAvg, seed, 1);
            ref_cfg.compute_fast_path = false;
            let reference = run(ref_cfg);
            for workers in [1usize, 4] {
                for fast in [true, false] {
                    let mut c = cfg(&dir, codec, SyncMode::ParallelFedAvg, seed, workers);
                    c.name = format!("pardet_compute_{codec}_{seed}_{workers}_{fast}");
                    c.compute_fast_path = fast;
                    let got = run(c);
                    assert_bit_identical(
                        &reference,
                        &got,
                        &format!(
                            "seed={seed} codec={codec} workers={workers} compute_fast={fast}"
                        ),
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn different_seeds_actually_diverge() {
    // guards against the comparison being vacuous (e.g. everything zero)
    let dir = sim_dir("diverge");
    let a = run(cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 1, 2));
    let b = run(cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 2, 2));
    assert_ne!(
        param_bits(&a.client),
        param_bits(&b.client),
        "different seeds produced identical client params"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// --- async scheduler -----------------------------------------------------

fn async_cfg(
    dir: &str,
    codec: &str,
    seed: u64,
    workers: usize,
    profile: &str,
    policy: StragglerPolicy,
) -> ExperimentConfig {
    let mut c = cfg(dir, codec, SyncMode::ParallelFedAvg, seed, workers);
    c.name = format!("pardet_async_{codec}_{seed}_{workers}_{}", policy.name());
    c.scheduler = SchedulerKind::Async;
    c.profile = profile.into();
    c.straggler = policy;
    c
}

#[test]
fn async_scheduler_is_bit_transparent() {
    // ≥2 seeds × ≥2 codecs × ≥3 straggler policies on a heterogeneous
    // wifi/lte fleet: workers = 4 and workers = 0 must reproduce the
    // workers = 1 run bit-for-bit (history, comm stats, parameters)
    let dir = sim_dir("async");
    for &seed in &[7u64, 1234] {
        for codec in ["slfac", "tk-sl"] {
            for policy in [
                StragglerPolicy::WaitAll,
                // drops the lte half of the fleet mid-flight (wifi
                // completes in ~0.03 s sim, lte needs ~0.2 s)
                StragglerPolicy::DeadlineDrop { deadline_s: 0.05 },
                StragglerPolicy::Quorum { k: 3 },
            ] {
                let reference = run(async_cfg(&dir, codec, seed, 1, "wifi/lte", policy));
                for workers in [4usize, 0] {
                    let got = run(async_cfg(&dir, codec, seed, workers, "wifi/lte", policy));
                    assert_bit_identical(
                        &reference,
                        &got,
                        &format!(
                            "async seed={seed} codec={codec} policy={} workers={workers}",
                            policy.name()
                        ),
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn async_wait_all_homogeneous_matches_sync_exactly() {
    // Fixed-rate codecs (payload size a pure function of the shape):
    // with homogeneous profiles every uplink of a step lands at the same
    // simulated instant, ties resolve to device-id order, and async
    // wait-all must match the sync scheduler exactly — byte totals,
    // per-round bytes, AND final parameters. (Content-adaptive codecs
    // like slfac have content-dependent payload sizes, so arrival order —
    // and hence server order — legitimately diverges; they are covered by
    // the round-1 uplink check below and the bit-transparency test.
    // mask-topk and nsc-sl are fixed-rate — payload size is a function of
    // shape alone — so they must hold the exact-match bar too.)
    let dir = sim_dir("async_vs_sync");
    for codec in ["identity", "uniform", "mask-topk", "nsc-sl"] {
        let sync = run(cfg(&dir, codec, SyncMode::ParallelFedAvg, 99, 2));
        let mut ac = cfg(&dir, codec, SyncMode::ParallelFedAvg, 99, 2);
        ac.scheduler = SchedulerKind::Async;
        let asy = run(ac);
        assert_eq!(
            sync.outcome.comm.uplink_bytes, asy.outcome.comm.uplink_bytes,
            "{codec}: uplink totals"
        );
        assert_eq!(
            sync.outcome.comm.downlink_bytes, asy.outcome.comm.downlink_bytes,
            "{codec}: downlink totals"
        );
        for (a, b) in sync
            .outcome
            .history
            .rounds
            .iter()
            .zip(&asy.outcome.history.rounds)
        {
            assert_eq!(a.uplink_bytes, b.uplink_bytes, "{codec}: per-round uplink");
            assert_eq!(a.downlink_bytes, b.downlink_bytes, "{codec}: per-round downlink");
            assert_eq!(b.dropped_devices, 0, "{codec}: wait-all never drops");
        }
        assert_eq!(
            param_bits(&sync.client),
            param_bits(&asy.client),
            "{codec}: client params"
        );
        assert_eq!(
            param_bits(&sync.server),
            param_bits(&asy.server),
            "{codec}: server params"
        );
    }
    // Adaptive codecs: round-1 uplink bytes are device-local (client
    // state is the shared init aggregate), so they must still agree.
    for codec in ["slfac", "tk-sl"] {
        let mk = |sched: SchedulerKind| {
            let mut c = cfg(&dir, codec, SyncMode::ParallelFedAvg, 99, 2);
            c.rounds = 1;
            c.scheduler = sched;
            c
        };
        let sync = run(mk(SchedulerKind::Sync));
        let asy = run(mk(SchedulerKind::Async));
        assert_eq!(
            sync.outcome.history.rounds[0].uplink_bytes,
            asy.outcome.history.rounds[0].uplink_bytes,
            "{codec}: round-1 uplink bytes"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn async_quorum_drops_deterministic_count() {
    // homogeneous fleet + quorum 2-of-4: completions tie, the seq order
    // resolves them, so exactly 2 devices drop every round
    let dir = sim_dir("quorum");
    let mut c = cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 11, 2);
    c.scheduler = SchedulerKind::Async;
    c.straggler = StragglerPolicy::Quorum { k: 2 };
    let r = run(c);
    assert_eq!(r.outcome.history.rounds.len(), 2);
    for m in &r.outcome.history.rounds {
        assert_eq!(m.dropped_devices, 2, "round {}", m.round);
        assert!(m.sim_time_s > 0.0);
        assert!(m.uplink_bytes > 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn async_deadline_all_dropped_is_graceful() {
    // a deadline tighter than any uplink: every device drops, no server
    // step runs, the aggregate is kept, and the run still completes
    let dir = sim_dir("deadline_all");
    let mut c = cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 5, 2);
    c.scheduler = SchedulerKind::Async;
    c.straggler = StragglerPolicy::DeadlineDrop { deadline_s: 1e-9 };
    let r = run(c);
    for m in &r.outcome.history.rounds {
        assert_eq!(m.dropped_devices, 4, "all devices drop");
        assert!(m.uplink_bytes > 0, "fan-out bytes were already on the wire");
        assert_eq!(m.downlink_bytes, 0, "no server step ⇒ no downlink");
        assert_eq!(m.train_loss, 0.0, "no executed server steps");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// --- contention model (server service + shared uplink) -------------------

#[test]
fn contention_model_is_bit_transparent() {
    // shared uplink + server service + client sampling, both schedulers:
    // workers = 4 and workers = 0 must reproduce the workers = 1 run
    // bit-for-bit — contention timing comes from event order, never from
    // thread scheduling
    let dir = sim_dir("contention");
    for &seed in &[7u64, 1234] {
        for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
            for codec in ["slfac", "tk-sl"] {
                let mk = |workers: usize| {
                    let mut c = cfg(&dir, codec, SyncMode::ParallelFedAvg, seed, workers);
                    c.name = format!("contention_{codec}_{seed}_{workers}");
                    c.scheduler = scheduler;
                    c.uplink = UplinkMode::Shared;
                    c.shared_uplink_bps = Some(20e6);
                    c.server_service_s = 0.001;
                    c.sampling = ClientSampling::Count(3);
                    c
                };
                let reference = run(mk(1));
                for workers in [4usize, 0] {
                    let got = run(mk(workers));
                    assert_bit_identical(
                        &reference,
                        &got,
                        &format!(
                            "contention seed={seed} sched={} codec={codec} workers={workers}",
                            scheduler.name()
                        ),
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_uplink_single_device_matches_private_bitwise() {
    // the contention acceptance edge: one device on a shared pipe of the
    // same capacity as its private link costs bit-for-bit the same —
    // history, comm stats, and parameters
    let dir = sim_dir("shared_single");
    for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
        let mk = |uplink: UplinkMode| {
            let mut c = cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 17, 2);
            c.name = format!("shared_single_{}", uplink.name());
            c.devices = 1;
            c.train_samples = 80;
            c.scheduler = scheduler;
            c.uplink = uplink;
            c
        };
        let private = run(mk(UplinkMode::Private));
        let shared = run(mk(UplinkMode::Shared));
        assert_bit_identical(
            &private,
            &shared,
            &format!("single device shared-vs-private, scheduler={}", scheduler.name()),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_uplink_contention_stretches_rounds_but_not_bytes() {
    // 4 devices on one pipe vs 4 private pipes of the same per-link rate:
    // identical bytes (compression is orthogonal to contention), strictly
    // longer simulated rounds (fair-share quarters the rate)
    let dir = sim_dir("shared_slow");
    let mk = |uplink: UplinkMode| {
        let mut c = cfg(&dir, "identity", SyncMode::ParallelFedAvg, 3, 2);
        c.name = format!("shared_slow_{}", uplink.name());
        c.scheduler = SchedulerKind::Async;
        c.uplink = uplink;
        // serialization-dominated regime so the fair-share split shows
        c.link.uplink_bps = 1e6;
        c.link.latency_s = 0.0;
        c
    };
    let private = run(mk(UplinkMode::Private));
    let shared = run(mk(UplinkMode::Shared));
    assert_eq!(
        private.outcome.comm.uplink_bytes, shared.outcome.comm.uplink_bytes,
        "contention must not change what is transmitted"
    );
    assert_eq!(
        param_bits(&private.client),
        param_bits(&shared.client),
        "contention is timing-only: training math identical"
    );
    for (p, s) in private
        .outcome
        .history
        .rounds
        .iter()
        .zip(&shared.outcome.history.rounds)
    {
        assert!(
            s.sim_time_s > 1.5 * p.sim_time_s,
            "round {}: shared {} should be well beyond private {}",
            p.round,
            s.sim_time_s,
            p.sim_time_s
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn server_service_time_queues_uplinks() {
    // service on: queue wait appears and rounds stretch; service off:
    // queue wait is exactly zero
    let dir = sim_dir("service");
    let mk = |service_s: f64| {
        let mut c = cfg(&dir, "identity", SyncMode::ParallelFedAvg, 9, 2);
        c.name = format!("service_{}", (service_s * 1e6) as u64);
        c.scheduler = SchedulerKind::Async;
        c.server_service_s = service_s;
        c
    };
    let instant = run(mk(0.0));
    let busy = run(mk(0.05));
    for m in &instant.outcome.history.rounds {
        assert_eq!(m.queue_wait_s.to_bits(), 0.0f64.to_bits(), "round {}", m.round);
    }
    for (i, m) in busy.outcome.history.rounds.iter().enumerate() {
        assert!(m.queue_wait_s > 0.0, "4 tied arrivals must queue (round {})", m.round);
        assert!(
            m.sim_time_s > instant.outcome.history.rounds[i].sim_time_s,
            "service time lengthens the round"
        );
    }
    // timing-only: same bytes, same parameters
    assert_eq!(
        param_bits(&instant.client),
        param_bits(&busy.client),
        "server service must not change training math"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_uplink_deadline_still_charges_sent_bytes() {
    // charge-at-send must hold in shared mode too: a deadline that
    // abandons every flow mid-pipe (or before its start event pops)
    // still counts the bytes that went out — same convention as the
    // private path, so uplink totals never depend on the contention mode
    let dir = sim_dir("shared_deadline");
    let mut c = cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 5, 2);
    c.scheduler = SchedulerKind::Async;
    c.uplink = UplinkMode::Shared;
    c.straggler = StragglerPolicy::DeadlineDrop { deadline_s: 1e-9 };
    let r = run(c);
    for m in &r.outcome.history.rounds {
        assert_eq!(m.dropped_devices, 4, "all devices drop");
        assert!(m.uplink_bytes > 0, "fan-out bytes were already on the wire");
        assert_eq!(m.downlink_bytes, 0, "no server step => no downlink");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// --- client sampling ------------------------------------------------------

#[test]
fn sample_k_at_least_fleet_size_is_full_participation() {
    // sample_k >= devices degrades to the unsampled run, bit-for-bit
    let dir = sim_dir("sample_full");
    let baseline = run(cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 21, 2));
    let mut c = cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 21, 2);
    c.sampling = ClientSampling::Count(64); // fleet is 4
    let sampled = run(c);
    assert_bit_identical(&baseline, &sampled, "sample_k >= devices");
    for m in &sampled.outcome.history.rounds {
        assert_eq!(m.sampled_devices, 4);
        assert_eq!(m.dropped_devices, 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sampling_cuts_traffic_and_reports_membership() {
    let dir = sim_dir("sample_half");
    let full = run(cfg(&dir, "identity", SyncMode::ParallelFedAvg, 33, 2));
    let mut c = cfg(&dir, "identity", SyncMode::ParallelFedAvg, 33, 2);
    c.sampling = ClientSampling::Fraction(0.5);
    let half = run(c);
    for (f, h) in full
        .outcome
        .history
        .rounds
        .iter()
        .zip(&half.outcome.history.rounds)
    {
        assert_eq!(h.sampled_devices, 2, "round(0.5 * 4) participants");
        assert_eq!(h.dropped_devices, 0, "sampling is not dropping");
        // identity codec: per-device payloads are constant, so half the
        // fleet transmits exactly half the bytes
        assert_eq!(h.uplink_bytes * 2, f.uplink_bytes, "round {}", f.round);
        assert_eq!(h.downlink_bytes * 2, f.downlink_bytes);
    }
    // sampling must also be bit-transparent across worker counts
    let mut c1 = cfg(&dir, "identity", SyncMode::ParallelFedAvg, 33, 1);
    c1.sampling = ClientSampling::Fraction(0.5);
    let seq = run(c1);
    assert_bit_identical(&seq, &half, "sampled run workers=1 vs 2");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sampling_composes_with_straggler_policies() {
    // quorum over the sampled subset on a heterogeneous fleet: still
    // deterministic across workers, drops counted within participants
    let dir = sim_dir("sample_quorum");
    let mk = |workers: usize| {
        let mut c = async_cfg(
            &dir,
            "slfac",
            11,
            workers,
            "wifi/lte",
            StragglerPolicy::Quorum { k: 2 },
        );
        c.sampling = ClientSampling::Count(3);
        c
    };
    let reference = run(mk(1));
    for workers in [4usize, 0] {
        assert_bit_identical(&reference, &run(mk(workers)), "sampled quorum");
    }
    for m in &reference.outcome.history.rounds {
        assert_eq!(m.sampled_devices, 3);
        assert_eq!(m.dropped_devices, 1, "3 sampled, quorum 2 => 1 dropped");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sim_training_makes_progress_and_accounts_bytes() {
    // the differential tests above would pass on a broken-but-deterministic
    // trainer; pin basic sanity of the sim path too
    let dir = sim_dir("sanity");
    // identity codec: no compression noise, so learning progress is clean
    let mut c = cfg(&dir, "identity", SyncMode::ParallelFedAvg, 7, 0);
    c.rounds = 4;
    c.batches_per_round = 4;
    let r = run(c);
    let rounds = &r.outcome.history.rounds;
    assert_eq!(rounds.len(), 4);
    let first = rounds.first().unwrap();
    let last = rounds.last().unwrap();
    assert!(
        last.train_loss < first.train_loss,
        "sim loss should drop: {} -> {}",
        first.train_loss,
        last.train_loss
    );
    assert!(first.uplink_bytes > 0 && first.downlink_bytes > 0);
    assert!(r.outcome.comm.total_bytes() > 0);
    assert!(r.outcome.comm.makespan_s > 0.0);
    assert!(CommStats::from_links(&[]).total_bytes() == 0);
    let _ = std::fs::remove_dir_all(&dir);
}
