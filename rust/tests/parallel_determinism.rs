//! Differential determinism: the thread-parallel round engine must be
//! **bit-transparent**. For multiple seeds, both sync modes, and both a
//! deterministic frequency codec (slfac) and a randomized spatial codec
//! (tk-sl), a run with `workers = 4` (and `workers = 0` = auto) must
//! reproduce the `workers = 1` run exactly: `TrainingHistory`, `CommStats`,
//! and final client/server parameters, all compared bit-for-bit.
//!
//! Runs on the sim executor backend (pure Rust, manifest only), so this
//! test needs no XLA runtime and no `make artifacts` — it always runs.

use slfac::config::{ExperimentConfig, SyncMode};
use slfac::coordinator::{TrainOutcome, Trainer};
use slfac::net::CommStats;
use slfac::runtime::{write_sim_manifest, ExecutorHandle, HostTensor, SimManifestSpec};

const BATCH: usize = 8;

fn sim_dir(label: &str) -> String {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = format!(
        "{}/slfac_pardet_{label}_{}_{}",
        std::env::temp_dir().display(),
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: BATCH,
            act_channels: 2,
            act_hw: 4,
        }],
    )
    .unwrap();
    dir
}

fn cfg(dir: &str, codec: &str, sync: SyncMode, seed: u64, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("pardet_{codec}_{seed}_{workers}"),
        codec: codec.into(),
        devices: 4,
        workers,
        sync,
        rounds: 2,
        batches_per_round: 2,
        batch_size: BATCH,
        train_samples: 160,
        test_samples: 2 * BATCH,
        seed,
        artifacts_dir: dir.into(),
        ..Default::default()
    }
}

struct RunResult {
    outcome: TrainOutcome,
    client: Vec<HostTensor>,
    server: Vec<HostTensor>,
}

fn run(cfg: ExperimentConfig) -> RunResult {
    let exec = ExecutorHandle::spawn_sim(&cfg.artifacts_dir, &["mnist".into()])
        .expect("sim executor");
    let mut trainer = Trainer::new(cfg, exec).expect("trainer");
    let outcome = trainer.run().expect("run");
    RunResult {
        outcome,
        client: trainer.client_params(),
        server: trainer.server_params(),
    }
}

fn param_bits(params: &[HostTensor]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert!(
        a.outcome.history.bit_eq(&b.outcome.history),
        "{label}: TrainingHistory diverged"
    );
    assert!(
        a.outcome.comm.bit_eq(&b.outcome.comm),
        "{label}: CommStats diverged: {:?} vs {:?}",
        a.outcome.comm,
        b.outcome.comm
    );
    assert_eq!(
        param_bits(&a.client),
        param_bits(&b.client),
        "{label}: client params diverged"
    );
    assert_eq!(
        param_bits(&a.server),
        param_bits(&b.server),
        "{label}: server params diverged"
    );
}

#[test]
fn parallel_workers_match_sequential_bitwise() {
    let dir = sim_dir("main");
    for &seed in &[7u64, 1234] {
        for (sync, sync_name) in [
            (SyncMode::ParallelFedAvg, "parallel"),
            (SyncMode::Sequential, "sequential"),
        ] {
            for codec in ["slfac", "tk-sl"] {
                let reference = run(cfg(&dir, codec, sync, seed, 1));
                for workers in [4usize, 0] {
                    let got = run(cfg(&dir, codec, sync, seed, workers));
                    assert_bit_identical(
                        &reference,
                        &got,
                        &format!("seed={seed} sync={sync_name} codec={codec} workers={workers}"),
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repeated_parallel_runs_are_self_consistent() {
    // same seed + same workers, run twice: scheduling noise between the
    // two runs must not leak into any result
    let dir = sim_dir("repeat");
    let a = run(cfg(&dir, "tk-sl", SyncMode::ParallelFedAvg, 42, 4));
    let b = run(cfg(&dir, "tk-sl", SyncMode::ParallelFedAvg, 42, 4));
    assert_bit_identical(&a, &b, "repeat tk-sl workers=4");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn different_seeds_actually_diverge() {
    // guards against the comparison being vacuous (e.g. everything zero)
    let dir = sim_dir("diverge");
    let a = run(cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 1, 2));
    let b = run(cfg(&dir, "slfac", SyncMode::ParallelFedAvg, 2, 2));
    assert_ne!(
        param_bits(&a.client),
        param_bits(&b.client),
        "different seeds produced identical client params"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sim_training_makes_progress_and_accounts_bytes() {
    // the differential tests above would pass on a broken-but-deterministic
    // trainer; pin basic sanity of the sim path too
    let dir = sim_dir("sanity");
    // identity codec: no compression noise, so learning progress is clean
    let mut c = cfg(&dir, "identity", SyncMode::ParallelFedAvg, 7, 0);
    c.rounds = 4;
    c.batches_per_round = 4;
    let r = run(c);
    let rounds = &r.outcome.history.rounds;
    assert_eq!(rounds.len(), 4);
    let first = rounds.first().unwrap();
    let last = rounds.last().unwrap();
    assert!(
        last.train_loss < first.train_loss,
        "sim loss should drop: {} -> {}",
        first.train_loss,
        last.train_loss
    );
    assert!(first.uplink_bytes > 0 && first.downlink_bytes > 0);
    assert!(r.outcome.comm.total_bytes() > 0);
    assert!(r.outcome.comm.makespan_s > 0.0);
    assert!(CommStats::from_links(&[]).total_bytes() == 0);
    let _ = std::fs::remove_dir_all(&dir);
}
