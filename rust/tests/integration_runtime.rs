//! Runtime integration: compile real artifacts through PJRT and verify the
//! HLO path numerically against the Rust reference stack.
//!
//! Requires `make artifacts`; each test skips with a notice when the
//! artifacts directory is missing. One executor is shared across tests
//! (compilation is the expensive part).

use slfac::dct::Dct2d;
use slfac::rng::Pcg32;
use slfac::runtime::{ExecutorHandle, HostTensor};
use slfac::tensor::Tensor;
use std::sync::{Mutex, OnceLock};

fn artifacts_root() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn executor() -> Option<&'static Mutex<ExecutorHandle>> {
    static EXEC: OnceLock<Option<Mutex<ExecutorHandle>>> = OnceLock::new();
    EXEC.get_or_init(|| {
        if !std::path::Path::new(&format!("{}/manifest.json", artifacts_root())).exists() {
            eprintln!("SKIP: artifacts missing — run `make artifacts`");
            return None;
        }
        Some(Mutex::new(
            ExecutorHandle::spawn(artifacts_root(), &["mnist".to_string()])
                .expect("executor spawn"),
        ))
    })
    .as_ref()
}

#[test]
fn idct_artifact_matches_rust_inverse_dct() {
    let Some(exec) = executor() else { return };
    let exec = exec.lock().unwrap();
    let mut rng = Pcg32::seeded(11);
    let coeffs = Tensor::randn(&[32, 16, 14, 14], 1.0, &mut rng);
    let out = exec
        .execute("mnist", "idct", vec![HostTensor::from_tensor(&coeffs)])
        .unwrap();
    let got = out.into_iter().next().unwrap().into_tensor().unwrap();
    let want = Dct2d::inverse_tensor(&coeffs);
    assert!(got.max_abs_diff(&want) < 1e-4);
}

#[test]
fn client_fwd_dct_output_matches_rust_dct_of_activations() {
    // The L1 Pallas kernel inside client_fwd must agree with the Rust DCT:
    // this is the end-to-end L1↔L3 consistency check on real artifacts.
    let Some(exec) = executor() else { return };
    let exec = exec.lock().unwrap();
    let init = exec.execute("mnist", "init", vec![]).unwrap();
    let manifest = slfac::runtime::ArtifactManifest::load(artifacts_root()).unwrap();
    let n_client = manifest.preset("mnist").unwrap().client_params.len();
    let cp: Vec<HostTensor> = init.into_iter().take(n_client).collect();

    let mut rng = Pcg32::seeded(13);
    let x = HostTensor::f32(
        &[32, 1, 28, 28],
        (0..32 * 28 * 28).map(|_| rng.normal()).collect(),
    );
    let mut inputs = cp;
    inputs.push(x);
    let mut out = exec.execute("mnist", "client_fwd", inputs).unwrap().into_iter();
    let act = out.next().unwrap().into_tensor().unwrap();
    let act_dct = out.next().unwrap().into_tensor().unwrap();
    assert_eq!(act.shape(), &[32, 16, 14, 14]);
    let want = Dct2d::forward_tensor(&act);
    let diff = act_dct.max_abs_diff(&want);
    assert!(diff < 1e-3, "pallas-vs-rust DCT diff {diff}");
    // activations are post-ReLU
    assert!(act.data().iter().all(|&v| v >= 0.0));
}

#[test]
fn server_step_learns_and_returns_consistent_grads() {
    let Some(exec) = executor() else { return };
    let exec = exec.lock().unwrap();
    let manifest = slfac::runtime::ArtifactManifest::load(artifacts_root()).unwrap();
    let pm = manifest.preset("mnist").unwrap();
    let (n_c, n_s) = (pm.client_params.len(), pm.server_params.len());
    let init = exec.execute("mnist", "init", vec![]).unwrap();
    let sp: Vec<HostTensor> = init[n_c..n_c + n_s].to_vec();
    let sm: Vec<HostTensor> = sp
        .iter()
        .map(|p| HostTensor::f32(p.dims(), vec![0.0; p.numel()]))
        .collect();

    let mut rng = Pcg32::seeded(17);
    let act = HostTensor::f32(
        &[32, 16, 14, 14],
        (0..32 * 16 * 14 * 14).map(|_| rng.normal().abs()).collect(),
    );
    let y = HostTensor::i32(&[32], (0..32).map(|i| (i % 10) as i32).collect());

    let mut inputs: Vec<HostTensor> = sp.iter().cloned().collect();
    inputs.extend(sm.iter().cloned());
    inputs.push(act.clone());
    inputs.push(y.clone());
    inputs.push(HostTensor::scalar_f32(0.05));
    let out = exec.execute("mnist", "server_step", inputs).unwrap();
    assert_eq!(out.len(), 2 * n_s + 4);
    let loss1 = out[2 * n_s].first();
    let gact = out[2 * n_s + 2].clone().into_tensor().unwrap();
    let gact_dct = out[2 * n_s + 3].clone().into_tensor().unwrap();
    assert!(loss1 > 0.0);
    // grad DCT consistency with the Rust transform
    let want = Dct2d::forward_tensor(&gact);
    assert!(gact_dct.max_abs_diff(&want) < 1e-3);

    // a second step from the updated params on the same batch lowers loss
    let new_sp = out[..n_s].to_vec();
    let new_sm = out[n_s..2 * n_s].to_vec();
    let mut inputs2: Vec<HostTensor> = new_sp;
    inputs2.extend(new_sm);
    inputs2.push(act);
    inputs2.push(y);
    inputs2.push(HostTensor::scalar_f32(0.05));
    let out2 = exec.execute("mnist", "server_step", inputs2).unwrap();
    let loss2 = out2[2 * n_s].first();
    assert!(loss2 < loss1, "loss {loss1} -> {loss2}");
}

#[test]
fn executor_reports_stats_and_rejects_unknown_artifacts() {
    let Some(exec) = executor() else { return };
    let exec = exec.lock().unwrap();
    assert!(exec.execute("mnist", "nope", vec![]).is_err());
    // at least the executions from other tests (order-independent: run one)
    let _ = exec.execute("mnist", "init", vec![]).unwrap();
    let stats = exec.stats().unwrap();
    assert!(stats.total_execs() >= 1);
    assert!(stats.per_artifact.contains_key("mnist/init"));
}
