//! Cross-codec property and robustness tests (no XLA required).
//!
//! These go beyond the per-module unit tests: wire-format fuzzing,
//! FQC invariants read back from real payload headers, f16 lattice
//! round-trip, and determinism under concurrency.

use slfac::codec::wire::{f16_to_f32, f32_to_f16, BodyReader, Payload};
use slfac::codec::{
    self, ActivationCodec, AfdUniformCodec, CodecParams, EasyQuantCodec, FeatureWiseCodec,
    FeatureWiseConfig, IdentityCodec, MagnitudeSelectCodec, MaskTopKCodec, MaskTopKConfig,
    NscSlCodec, NscSlConfig, PowerQuantCodec, SlAccCodec, SlAccConfig, SlFacCodec,
    SlFacConfig, SplitFcCodec, SplitFcConfig, StdSelectCodec, TopKCodec, TopKConfig,
    UniformLinearCodec,
};
use slfac::dct::Dct2d;
use slfac::rng::Pcg32;
use slfac::testing::prop;

/// Compile-time assertion: every registered codec type (and the boxed
/// trait object the factory hands out) is `Send + Sync`, i.e. safe to
/// share across the parallel round engine's worker threads.
#[allow(dead_code)]
fn every_registered_codec_is_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<SlFacCodec>();
    check::<AfdUniformCodec>();
    check::<TopKCodec>();
    check::<SplitFcCodec>();
    check::<PowerQuantCodec>();
    check::<EasyQuantCodec>();
    check::<MagnitudeSelectCodec>();
    check::<StdSelectCodec>();
    check::<UniformLinearCodec>();
    check::<IdentityCodec>();
    check::<SlAccCodec>();
    check::<FeatureWiseCodec>();
    check::<MaskTopKCodec>();
    check::<NscSlCodec>();
    check::<Box<dyn ActivationCodec>>();
    check::<std::sync::Arc<dyn ActivationCodec>>();
}

#[test]
fn payload_fuzz_never_panics() {
    // Random byte strings must be rejected gracefully, never panic.
    let mut rng = Pcg32::seeded(0xF022);
    for _ in 0..2000 {
        let len = rng.below(200) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let _ = Payload::from_bytes(&bytes); // Result either way
    }
}

#[test]
fn truncated_and_bitflipped_payloads_fail_closed() {
    let params = CodecParams::default();
    let x = codec::smooth_activations(&[2, 4, 8, 8], 5);
    let mut rng = Pcg32::seeded(0xBADC);
    for name in codec::ALL_CODECS {
        let c = codec::by_name(name, &params).unwrap();
        let input = if c.frequency_domain() {
            Dct2d::forward_tensor(&x)
        } else {
            x.clone()
        };
        let p = c.compress(&input).unwrap();
        // truncation at random points: decompress must error or return the
        // right shape — never panic, never return a wrong-shaped tensor.
        for _ in 0..20 {
            let cut = rng.below(p.body.len().max(1) as u32) as usize;
            let mut t = p.clone();
            t.body.truncate(cut);
            if let Ok(out) = c.decompress(&t) {
                assert_eq!(out.shape(), &[2, 4, 8, 8], "{name}");
            }
        }
        // single random bit flips: same contract, and all outputs finite
        // or an error (quantized formats cannot produce NaN from levels,
        // but header floats can — codecs must still return *something*
        // sane or an error).
        for _ in 0..20 {
            let mut t = p.clone();
            if t.body.is_empty() {
                continue;
            }
            let i = rng.below(t.body.len() as u32) as usize;
            t.body[i] ^= 1 << rng.below(8);
            let _ = c.decompress(&t);
        }
    }
}

#[test]
fn header_mutations_across_all_codecs_fail_closed() {
    // Stomp every serialized header byte of a real payload from every
    // registered codec: parsing either rejects the bytes or yields a
    // payload that decompresses to an error / a sane tensor. No panics,
    // and no allocation larger than the wire shape guard allows.
    use slfac::codec::wire::{HEADER_BYTES, MAX_WIRE_ELEMS};
    let params = CodecParams::default();
    let x = codec::smooth_activations(&[2, 4, 8, 8], 77);
    let mut rng = Pcg32::seeded(0x4EAD);
    for name in codec::ALL_CODECS {
        let c = codec::by_name(name, &params).unwrap();
        let input = if c.frequency_domain() {
            Dct2d::forward_tensor(&x)
        } else {
            x.clone()
        };
        let wire = c.compress(&input).unwrap().to_bytes();
        for off in 0..HEADER_BYTES {
            for stomp in [0x01u8, 0x80, 0xFF, rng.next_u32() as u8] {
                let mut bytes = wire.clone();
                bytes[off] ^= stomp;
                if bytes[off] == wire[off] {
                    continue;
                }
                let Ok(p) = Payload::from_bytes(&bytes) else {
                    continue;
                };
                assert!(
                    p.shape.iter().product::<usize>() <= MAX_WIRE_ELEMS,
                    "{name}: parser accepted an implausible shape {:?}",
                    p.shape
                );
                let _ = c.decompress(&p); // Err or garbage, never a panic
            }
        }
    }
}

#[test]
fn implausible_shape_headers_rejected_before_allocation() {
    // A corrupted shape field claiming a huge tensor must be rejected at
    // parse time — decoders never see it, so no OOM-sized allocation can
    // happen. 2^28 elements is the documented ceiling.
    use slfac::codec::wire::HEADER_BYTES;
    let header = |shape: [u32; 4]| {
        let mut bytes = Vec::with_capacity(HEADER_BYTES);
        bytes.extend_from_slice(b"SLFC");
        bytes.push(1); // version
        bytes.push(0); // kind
        bytes.extend_from_slice(&[0u8; 2]);
        for d in shape {
            bytes.extend_from_slice(&d.to_le_bytes());
        }
        bytes.extend_from_slice(&0u32.to_le_bytes()); // empty body
        bytes
    };
    // at the ceiling: parses
    assert!(Payload::from_bytes(&header([1, 1, 1 << 14, 1 << 14])).is_ok());
    // over the ceiling, including products that overflow usize: rejected
    for shape in [
        [1, 1, 1 << 14, (1 << 14) + 1],
        [u32::MAX, u32::MAX, u32::MAX, u32::MAX],
        [1 << 16, 1 << 16, 1 << 16, 1],
    ] {
        let err = Payload::from_bytes(&header(shape)).unwrap_err().to_string();
        assert!(err.contains("implausible"), "shape {shape:?}: {err}");
    }
}

#[test]
fn fqc_bit_widths_respect_bounds_in_real_payloads() {
    prop("fqc header invariants", 40, |g| {
        let shape = g.bchw_shape();
        let x = g.tensor(&shape, 1.5);
        let coeffs = Dct2d::forward_tensor(&x);
        let cfg = SlFacConfig {
            theta: *g.choose(&[0.6f64, 0.8, 0.9, 0.95]),
            ..Default::default()
        };
        let c = SlFacCodec::new(cfg);
        let p = c.compress(&coeffs).unwrap();
        let [b, ch, m, n] = p.shape;
        let plane = m * n;
        let mut r = BodyReader::new(&p.body);
        for _ in 0..b * ch {
            let k = r.u16().unwrap() as usize;
            let b_low = r.u8().unwrap() as u32;
            let b_high = r.u8().unwrap() as u32;
            assert!(k >= 1 && k <= plane, "k*={k}");
            assert!((cfg.alloc.b_min..=cfg.alloc.b_max).contains(&b_low));
            assert!((cfg.alloc.b_min..=cfg.alloc.b_max).contains(&b_high));
            // NOTE: b_low >= b_high is NOT an invariant of Eq. 7 — on
            // near-flat spectra (k/len > θ) F_h's *mean* energy can exceed
            // F_l's; only the [b_min, b_max] bounds are guaranteed.
            let min_low = r.f32().unwrap();
            let max_low = r.f32().unwrap();
            assert!(min_low <= max_low);
            let mut bits = k * b_low as usize;
            if k < plane {
                let min_high = r.f32().unwrap();
                let max_high = r.f32().unwrap();
                assert!(min_high <= max_high);
                bits += (plane - k) * b_high as usize;
            }
            r.bytes((bits + 7) / 8).unwrap();
        }
        assert_eq!(r.remaining(), 0);
    });
}

#[test]
fn f16_lattice_roundtrip_exact() {
    // Every representable finite half value must round-trip bit-exactly
    // through f32 (f16 -> f32 -> f16).
    let mut checked = 0u32;
    for h in 0..=u16::MAX {
        let exp = (h >> 10) & 0x1F;
        if exp == 0x1F {
            continue; // inf/nan
        }
        let f = f16_to_f32(h);
        let back = f32_to_f16(f);
        // -0.0 and 0.0 both fine but must preserve bits exactly
        assert_eq!(back, h, "h={h:#06x} f={f}");
        checked += 1;
    }
    assert!(checked > 60_000);
}

#[test]
fn slfac_is_threadsafe_and_deterministic() {
    let x = Dct2d::forward_tensor(&codec::smooth_activations(&[4, 8, 14, 14], 9));
    let c = std::sync::Arc::new(SlFacCodec::new(SlFacConfig::default()));
    let reference = c.compress(&x).unwrap().to_bytes();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let c = c.clone();
            let x = x.clone();
            let reference = reference.clone();
            std::thread::spawn(move || {
                for _ in 0..10 {
                    assert_eq!(c.compress(&x).unwrap().to_bytes(), reference);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn wire_bytes_equals_serialized_length_for_all_codecs() {
    let params = CodecParams::default();
    let x = codec::smooth_activations(&[1, 3, 10, 12], 21);
    for name in codec::ALL_CODECS {
        let c = codec::by_name(name, &params).unwrap();
        let input = if c.frequency_domain() {
            Dct2d::forward_tensor(&x)
        } else {
            x.clone()
        };
        let p = c.compress(&input).unwrap();
        assert_eq!(p.wire_bytes(), p.to_bytes().len(), "{name}");
    }
}

#[test]
fn property_uniform_roundtrip_bounds_error_by_step() {
    // min-max linear quantization at b bits: every element reconstructs
    // within half a quantization step of the clamped input
    prop("uniform roundtrip step bound", 60, |g| {
        let shape = g.bchw_shape();
        let x = g.tensor(&shape, *g.choose(&[0.3f32, 1.0, 5.0]));
        let bits = g.usize_in(2, 12) as u32;
        let c = UniformLinearCodec::new(bits);
        let p = c.compress(&x).unwrap();
        let back = c.decompress(&p).unwrap();
        assert_eq!(back.shape(), x.shape());
        let (lo, hi) = x.min_max();
        let levels = (1u32 << bits) - 1;
        let step = (hi - lo).max(1e-12) / levels as f32;
        let worst = back.max_abs_diff(&x);
        assert!(
            worst <= step / 2.0 + step * 1e-3 + 1e-6,
            "bits={bits} worst={worst} step={step}"
        );
    });
}

#[test]
fn property_topk_keeps_exactly_the_heavy_mass() {
    prop("topk keeps heavy mass", 60, |g| {
        let shape = g.bchw_shape();
        let x = g.tensor(&shape, 1.0);
        let keep = *g.choose(&[0.1f64, 0.25, 0.5, 1.0]);
        let c = TopKCodec::new(TopKConfig {
            keep_fraction: keep,
            random_fraction: 0.0,
            seed: 3,
        });
        let p = c.compress(&x).unwrap();
        let back = c.decompress(&p).unwrap();
        assert_eq!(back.shape(), x.shape());
        let per_sample: usize = shape[1] * shape[2] * shape[3];
        let k_top = ((per_sample as f64 * keep).ceil() as usize).clamp(1, per_sample);
        for bi in 0..shape[0] {
            let sample = &x.data()[bi * per_sample..(bi + 1) * per_sample];
            let rec = &back.data()[bi * per_sample..(bi + 1) * per_sample];
            let nonzero = rec.iter().filter(|&&v| v != 0.0).count();
            // f16 rounding can zero a tiny kept value, never add one
            assert!(nonzero <= k_top, "kept {nonzero} > k_top {k_top}");
            // every reconstructed element matches its source in f16
            for (r, s) in rec.iter().zip(sample) {
                if *r != 0.0 {
                    assert!((r - s).abs() <= s.abs() * 0.01 + 1e-3);
                }
            }
        }
    });
}

#[test]
fn property_splitfc_roundtrip_and_channel_budget() {
    prop("splitfc roundtrip", 60, |g| {
        let shape = g.bchw_shape();
        let x = g.tensor(&shape, 1.0);
        let keep = *g.choose(&[0.25f64, 0.5, 1.0]);
        let bits = g.usize_in(2, 8) as u32;
        let c = SplitFcCodec::new(SplitFcConfig {
            keep_fraction: keep,
            bits,
        });
        let p = c.compress(&x).unwrap();
        let back = c.decompress(&p).unwrap();
        assert_eq!(back.shape(), x.shape());
        for v in back.data() {
            assert!(v.is_finite());
        }
        // serialized form is stable through the wire
        let p2 = Payload::from_bytes(&p.to_bytes()).unwrap();
        assert_eq!(c.decompress(&p2).unwrap().data(), back.data());
    });
}

#[test]
fn property_slacc_header_bounds_and_kernel_identity() {
    // SL-ACC wire invariants on random tensors: every channel's bit width
    // sits in [b_min, b_max], ranges are ordered, the body parses exactly —
    // and the fused kernel is bit-identical to the reference
    prop("sl-acc header invariants", 60, |g| {
        let shape = g.bchw_shape();
        let x = g.tensor(&shape, *g.choose(&[0.3f32, 1.0, 4.0]));
        let alloc = slfac::quant::AllocationConfig::default();
        let c = SlAccCodec::new(SlAccConfig {
            alloc,
            fast_path: true,
        });
        let p = c.compress(&x).unwrap();
        let [b, ch, m, n] = p.shape;
        let plane = m * n;
        let mut r = BodyReader::new(&p.body);
        for _ in 0..b * ch {
            let bits = r.u8().unwrap() as u32;
            assert!((alloc.b_min..=alloc.b_max).contains(&bits), "bits={bits}");
            let min = r.f32().unwrap();
            let max = r.f32().unwrap();
            assert!(min <= max);
            r.bytes((plane * bits as usize + 7) / 8).unwrap();
        }
        assert_eq!(r.remaining(), 0);
        let reference = SlAccCodec::new(SlAccConfig {
            alloc,
            fast_path: false,
        });
        assert_eq!(p.to_bytes(), reference.compress(&x).unwrap().to_bytes());
        let back = c.decompress(&p).unwrap();
        assert_eq!(back.shape(), x.shape());
        for v in back.data() {
            assert!(v.is_finite());
        }
    });
}

#[test]
fn property_featurewise_size_monotone_in_threshold() {
    // raising drop_threshold can only drop more channels, so the payload
    // never grows; constant tensors reconstruct exactly from f16 means
    prop("feature-wise threshold monotonicity", 60, |g| {
        let shape = g.bchw_shape();
        let x = g.tensor(&shape, 1.0);
        let mut last = usize::MAX;
        for thr in [0.0f64, 0.3, 0.7, 1.0] {
            let c = FeatureWiseCodec::new(FeatureWiseConfig {
                drop_threshold: thr,
                ..Default::default()
            });
            let p = c.compress(&x).unwrap();
            assert!(
                p.wire_bytes() <= last,
                "thr={thr}: {} > {last}",
                p.wire_bytes()
            );
            last = p.wire_bytes();
            let back = c.decompress(&p).unwrap();
            assert_eq!(back.shape(), x.shape());
            for v in back.data() {
                assert!(v.is_finite());
            }
        }
        // degenerate: an all-constant tensor drops every channel and
        // reconstructs exactly (2.5 is f16-representable)
        let flat = slfac::tensor::Tensor::full(&shape, 2.5);
        let c = FeatureWiseCodec::new(FeatureWiseConfig::default());
        let back = c.decompress(&c.compress(&flat).unwrap()).unwrap();
        assert_eq!(back.data(), flat.data());
    });
}

#[test]
fn property_masktopk_fixed_rate_and_size_monotone_in_bits() {
    prop("mask-topk size monotonicity", 60, |g| {
        let shape = g.bchw_shape();
        let x = g.tensor(&shape, 1.0);
        let keep = *g.choose(&[0.1f64, 0.25, 0.5, 1.0]);
        let mut last = 0usize;
        for bits in [2u32, 4, 8] {
            let c = MaskTopKCodec::new(MaskTopKConfig {
                keep_fraction: keep,
                bits,
            });
            let p = c.compress(&x).unwrap();
            assert!(p.wire_bytes() >= last, "bits={bits}");
            last = p.wire_bytes();
            // fixed-rate: an all-zero tensor of the same shape costs the
            // same bytes (and reconstructs exactly)
            let z = slfac::tensor::Tensor::zeros(&shape);
            let pz = c.compress(&z).unwrap();
            assert_eq!(pz.wire_bytes(), p.wire_bytes());
            assert_eq!(c.decompress(&pz).unwrap().data(), z.data());
            let back = c.decompress(&p).unwrap();
            assert_eq!(back.shape(), x.shape());
            for v in back.data() {
                assert!(v.is_finite());
            }
        }
    });
}

#[test]
fn property_nscsl_size_monotone_in_rank_and_error_bounded_at_full_rank() {
    prop("nsc-sl rank monotonicity", 40, |g| {
        let shape = g.bchw_shape();
        let x = g.tensor(&shape, 1.0);
        let mut last = 0usize;
        for frac in [0.25f64, 0.5, 1.0] {
            let c = NscSlCodec::new(NscSlConfig {
                subspace_fraction: frac,
                bits: 8,
                seed: 7,
            });
            let p = c.compress(&x).unwrap();
            assert!(p.wire_bytes() >= last, "frac={frac}");
            last = p.wire_bytes();
            let back = c.decompress(&p).unwrap();
            assert_eq!(back.shape(), x.shape());
            for v in back.data() {
                assert!(v.is_finite());
            }
            // orthogonal projection never amplifies: reconstruction error
            // is bounded by the input norm plus quantization slack
            let err = back.rel_l2_error(&x);
            assert!(err < 1.2, "frac={frac}: rel err {err}");
            if frac == 1.0 {
                assert!(err < 0.05, "full rank must be near-exact, err {err}");
            }
        }
    });
}

#[test]
fn fuzz_uniform_topk_splitfc_reject_corruption_without_panicking() {
    // truncations and random byte stomps on real payloads: must error or
    // return a correctly-shaped finite-or-error tensor, never panic
    let mut rng = Pcg32::seeded(0xFA22);
    let x = codec::smooth_activations(&[2, 4, 8, 8], 55);
    let codecs: Vec<Box<dyn ActivationCodec>> = vec![
        Box::new(UniformLinearCodec::new(4)),
        Box::new(TopKCodec::new(TopKConfig::default())),
        Box::new(SplitFcCodec::new(SplitFcConfig::default())),
    ];
    for c in &codecs {
        let p = c.compress(&x).unwrap();
        for _ in 0..60 {
            let mut t = p.clone();
            match rng.below(3) {
                0 => {
                    let cut = rng.below(t.body.len().max(1) as u32) as usize;
                    t.body.truncate(cut);
                }
                1 => {
                    if !t.body.is_empty() {
                        let i = rng.below(t.body.len() as u32) as usize;
                        t.body[i] = rng.next_u32() as u8;
                    }
                }
                _ => {
                    let extra = rng.below(16) as usize;
                    t.body.resize(t.body.len() + extra, 0xAB);
                }
            }
            if let Ok(out) = c.decompress(&t) {
                assert_eq!(out.shape(), &[2, 4, 8, 8], "{}", c.name());
            }
        }
    }
}

#[test]
fn slfac_ratio_improves_on_smoother_data() {
    // Smoother input (energy more concentrated) ⇒ smaller k* ⇒ fewer bits.
    let smooth = codec::smooth_activations(&[4, 8, 14, 14], 30);
    let mut rng = Pcg32::seeded(31);
    let noisy = slfac::tensor::Tensor::randn(&[4, 8, 14, 14], 1.0, &mut rng);
    let c = SlFacCodec::new(SlFacConfig::default());
    let p_smooth = c.compress(&Dct2d::forward_tensor(&smooth)).unwrap();
    let p_noisy = c.compress(&Dct2d::forward_tensor(&noisy)).unwrap();
    assert!(
        p_smooth.wire_bytes() < p_noisy.wire_bytes(),
        "smooth {} vs noisy {}",
        p_smooth.wire_bytes(),
        p_noisy.wire_bytes()
    );
}
