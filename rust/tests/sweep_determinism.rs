//! Sweep orchestrator determinism: the journal and the `slfac-sweep/1`
//! report are **byte-identical** functions of (spec, seed) alone —
//! independent of the worker count, and independent of whether the sweep
//! ran uninterrupted or was stopped mid-grid and resumed (even through a
//! torn journal tail). This is the resume contract from the sweep module
//! docs, pinned differentially at workers 1 and 4.
//!
//! Runs on the sim executor backend (pure Rust, manifest only), so this
//! test needs no XLA runtime and no `make artifacts` — it always runs.

use slfac::json::Json;
use slfac::sweep::{page, run_sweep, sweep_status, Journal, SweepOptions, SweepSpec};

/// Unique per-test temp root (the shared artifacts dir and every sweep's
/// out_dir live under it).
fn temp_root(label: &str) -> String {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    format!(
        "{}/slfac_sweepdet_{label}_{}_{}",
        std::env::temp_dir().display(),
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    )
}

/// A 2 codecs × 2 seeds grid of tiny sim-backend runs. All variants of
/// one test share this spec (and so its artifacts dir and fingerprint);
/// only the sweep's `out_dir` differs, so journals are comparable
/// byte-for-byte. The orchestrator writes the sim manifest itself on
/// first use (`sim_manifest` key).
fn smoke_spec(root: &str) -> SweepSpec {
    let text = format!(
        r#"{{
          "name": "det",
          "backend": "sim",
          "sim_manifest": {{"preset": "mnist", "batch_size": 8,
                            "act_channels": 2, "act_hw": 4}},
          "base": {{
            "artifacts_dir": "{root}/artifacts",
            "dataset": "mnist",
            "devices": 3,
            "workers": 1,
            "train_samples": 48,
            "test_samples": 8,
            "rounds": 1,
            "batches_per_round": 1,
            "batch_size": 8
          }},
          "axes": [
            {{"codec": ["slfac", "pq-sl"]}},
            {{"seed": [7, 1234]}}
          ]
        }}"#
    );
    SweepSpec::from_json(&Json::parse(&text).unwrap()).unwrap()
}

fn opts(root: &str, sub: &str, workers: usize, stop_after: Option<usize>) -> SweepOptions {
    SweepOptions {
        workers: Some(workers),
        stop_after,
        out_dir: format!("{root}/{sub}"),
        journal_path: None,
        checkpoint_every: 0,
    }
}

fn journal_bytes(root: &str, sub: &str) -> Vec<u8> {
    std::fs::read(format!("{root}/{sub}/det/journal.jsonl")).expect("journal exists")
}

fn report_bytes(root: &str, sub: &str) -> Vec<u8> {
    std::fs::read(format!("{root}/{sub}/det/report.json")).expect("report exists")
}

#[test]
fn interrupted_resume_is_bit_identical_at_workers_1_and_4() {
    let root = temp_root("resume");
    let spec = smoke_spec(&root);
    let mut full_journals: Vec<Vec<u8>> = Vec::new();
    for w in [1usize, 4] {
        // uninterrupted reference sweep
        let full = format!("full_w{w}");
        let out = run_sweep(&spec, &opts(&root, &full, w, None)).unwrap();
        assert_eq!((out.grid, out.completed, out.executed), (4, 4, 4));
        assert!(!out.interrupted);

        // same grid, stopped after 3 runs, then resumed
        let res = format!("resumed_w{w}");
        let out = run_sweep(&spec, &opts(&root, &res, w, Some(3))).unwrap();
        assert!(out.interrupted);
        assert_eq!((out.completed, out.executed), (3, 3));
        let out = run_sweep(&spec, &opts(&root, &res, w, None)).unwrap();
        assert!(!out.interrupted);
        assert_eq!((out.completed, out.skipped, out.executed), (4, 3, 1));

        assert_eq!(
            journal_bytes(&root, &full),
            journal_bytes(&root, &res),
            "workers={w}: resumed journal must be byte-identical"
        );
        assert_eq!(
            report_bytes(&root, &full),
            report_bytes(&root, &res),
            "workers={w}: resumed report must be byte-identical"
        );
        full_journals.push(journal_bytes(&root, &full));
    }
    // and across worker counts
    assert_eq!(
        full_journals[0], full_journals[1],
        "journal bytes must not depend on the worker count"
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn report_pages_are_stable_while_the_sweep_progresses() {
    let root = temp_root("pages");
    let spec = smoke_spec(&root);
    let o = opts(&root, "out", 2, Some(3));
    run_sweep(&spec, &o).unwrap();
    let jpath = format!("{root}/out/det/journal.jsonl");

    // first full page over the partial (3 of 4 runs) journal
    let j = Journal::open(&jpath).unwrap();
    assert_eq!(j.completed(), 3);
    let partial_p1 = page(j.header(), j.records(), None, 2).to_string();
    assert_eq!(
        Json::parse(&partial_p1)
            .unwrap()
            .get("next_cursor")
            .and_then(|c| c.as_str()),
        Some("run:1")
    );

    // finish the sweep; the full page must not have changed a byte except
    // the `completed` counter — strip it and compare the rest, then walk
    // the cursor chain over the complete journal
    run_sweep(&spec, &opts(&root, "out", 2, None)).unwrap();
    let j = Journal::open(&jpath).unwrap();
    assert_eq!(j.completed(), 4);
    let complete_p1 = page(j.header(), j.records(), None, 2).to_string();
    let strip = |s: &str| {
        let mut doc = match Json::parse(s).unwrap() {
            Json::Obj(m) => m,
            _ => panic!("page is an object"),
        };
        doc.remove("completed").expect("page has 'completed'");
        Json::Obj(doc).to_string()
    };
    assert_eq!(
        strip(&partial_p1),
        strip(&complete_p1),
        "a full page must be stable as the journal grows"
    );

    // cursor chain: run:1 -> runs [2, 3] -> end
    let p2 = page(j.header(), j.records(), Some(1), 2);
    let ids: Vec<usize> = p2
        .get("runs")
        .and_then(|r| r.as_arr())
        .unwrap()
        .iter()
        .map(|r| r.get("run_id").and_then(|v| v.as_usize()).unwrap())
        .collect();
    assert_eq!(ids, [2, 3]);
    assert_eq!(p2.get("next_cursor"), Some(&Json::Null));
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn journal_of_a_different_grid_is_rejected() {
    let root = temp_root("foreign");
    let spec = smoke_spec(&root);
    run_sweep(&spec, &opts(&root, "out", 1, Some(1))).unwrap();

    // same sweep name, different seed axis ⇒ different fingerprint
    let other = {
        let mut j = match spec.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        j.insert(
            "axes".to_string(),
            Json::parse(r#"[{"codec": ["slfac", "pq-sl"]}, {"seed": [8, 1234]}]"#).unwrap(),
        );
        SweepSpec::from_json(&Json::Obj(j)).unwrap()
    };
    let err = format!(
        "{:#}",
        run_sweep(&other, &opts(&root, "out", 1, None)).unwrap_err()
    );
    assert!(err.contains("journal"), "{err}");
    assert!(err.contains("fingerprint"), "{err}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn torn_journal_tail_resumes_byte_identically() {
    let root = temp_root("torn");
    let spec = smoke_spec(&root);
    run_sweep(&spec, &opts(&root, "full", 1, None)).unwrap();

    run_sweep(&spec, &opts(&root, "torn", 1, Some(2))).unwrap();
    // simulate a crash mid-append: an unterminated half-record
    let jpath = format!("{root}/torn/det/journal.jsonl");
    let mut bytes = std::fs::read(&jpath).unwrap();
    bytes.extend_from_slice(b"{\"run_id\":2,\"name\":\"det_");
    std::fs::write(&jpath, &bytes).unwrap();

    run_sweep(&spec, &opts(&root, "torn", 1, None)).unwrap();
    assert_eq!(journal_bytes(&root, "full"), journal_bytes(&root, "torn"));
    assert_eq!(report_bytes(&root, "full"), report_bytes(&root, "torn"));
    let _ = std::fs::remove_dir_all(&root);
}

/// Mid-wave crash with per-run checkpoints: the kill lands *after* some
/// runs finished executing and wrote their round checkpoints, but
/// *before* their journal records hit disk (exactly the window a wave
/// barrier leaves open). On re-run those runs must come back from their
/// checkpoints — the trainer restores the full history and comm state
/// without recomputing a single round — and the journal + report must be
/// byte-identical to a sweep that never crashed (and never checkpointed).
#[test]
fn mid_wave_crash_with_run_checkpoints_resumes_byte_identically() {
    let root = temp_root("midwave");
    let spec = smoke_spec(&root);
    // uninterrupted reference, checkpoints off
    run_sweep(&spec, &opts(&root, "full", 2, None)).unwrap();

    // checkpointed sweep, run to completion first so every run's
    // checkpoint exists on disk
    let crash_opts = SweepOptions {
        checkpoint_every: 1,
        ..opts(&root, "crash", 2, None)
    };
    run_sweep(&spec, &crash_opts).unwrap();
    for run in [
        "det_slfac_seed7",
        "det_slfac_seed1234",
        "det_pq-sl_seed7",
        "det_pq-sl_seed1234",
    ] {
        assert!(
            std::path::Path::new(&format!("{root}/crash/det/ckpt/{run}")).exists(),
            "per-run checkpoint dir missing for {run}"
        );
    }

    // simulate the crash: drop the journal tail (header + 2 records
    // survive), leaving runs 2 and 3 checkpointed but unjournaled
    let jpath = format!("{root}/crash/det/journal.jsonl");
    let text = std::fs::read_to_string(&jpath).unwrap();
    let keep: Vec<&str> = text.lines().take(3).collect();
    std::fs::write(&jpath, format!("{}\n", keep.join("\n"))).unwrap();

    // re-run: runs 0-1 skip via the journal, runs 2-3 restore entirely
    // from their checkpoints (zero rounds recomputed)
    let out = run_sweep(&spec, &crash_opts).unwrap();
    assert_eq!((out.completed, out.skipped, out.executed), (4, 2, 2));

    assert_eq!(
        journal_bytes(&root, "full"),
        journal_bytes(&root, "crash"),
        "journal after a mid-wave crash + checkpoint resume must be \
         byte-identical to the uninterrupted, checkpoint-free sweep"
    );
    assert_eq!(
        report_bytes(&root, "full"),
        report_bytes(&root, "crash"),
        "report after a mid-wave crash + checkpoint resume must be \
         byte-identical to the uninterrupted, checkpoint-free sweep"
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn status_tracks_grid_progress() {
    let root = temp_root("status");
    let spec = smoke_spec(&root);
    let o = opts(&root, "out", 1, Some(3));
    let count = |st: &Json, key: &str| st.get(key).and_then(|v| v.as_usize()).unwrap();

    let st = sweep_status(&spec, &o).unwrap();
    assert_eq!((count(&st, "completed"), count(&st, "pending")), (0, 4));

    run_sweep(&spec, &o).unwrap();
    let st = sweep_status(&spec, &o).unwrap();
    assert_eq!((count(&st, "completed"), count(&st, "pending")), (3, 1));
    assert_eq!(st.get("schema").and_then(|s| s.as_str()), Some("slfac-sweep-status/1"));

    run_sweep(&spec, &opts(&root, "out", 1, None)).unwrap();
    let st = sweep_status(&spec, &o).unwrap();
    assert_eq!((count(&st, "completed"), count(&st, "pending")), (4, 0));
    let _ = std::fs::remove_dir_all(&root);
}
