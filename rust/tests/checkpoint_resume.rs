//! Crash-durable checkpoint/resume: the headline contract is that a run
//! killed at a round boundary and resumed from its checkpoint is
//! **bit-identical** to a run that never stopped — final client and
//! server parameters, the full per-round `TrainingHistory`, `CommStats`,
//! and the metrics CSV bytes (modulo the host-clock `wall_time_s`
//! column, which no simulated quantity depends on).
//!
//! The matrix covers workers 1 and 4 × both schedulers × the
//! device-resident fast path and the artifact reference path, plus a
//! fault-active scenario (loss + corruption + crashes + a server outage
//! composed with checkpointing). Fail-closed behavior is pinned
//! separately: torn, corrupt, foreign-config, and non-checkpoint files
//! must all be rejected with named errors, and retention must keep only
//! the last k snapshots.
//!
//! Interruption uses the trainer's runtime-only `set_stop_after` hook —
//! *not* a smaller `rounds` — so the interrupted run's config (and hence
//! the fingerprint pinned in the checkpoint header) is identical to the
//! uninterrupted run's.
//!
//! Runs on the sim executor backend — no XLA, no artifacts.

use slfac::config::ExperimentConfig;
use slfac::coordinator::{TrainOutcome, Trainer};
use slfac::runtime::{write_sim_manifest, ExecutorHandle, HostTensor, SimManifestSpec};
use slfac::transport::{FaultConfig, SchedulerKind};

const BATCH: usize = 8;

fn sim_dir(label: &str) -> String {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = format!(
        "{}/slfac_ckpt_{label}_{}_{}",
        std::env::temp_dir().display(),
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: BATCH,
            act_channels: 2,
            act_hw: 4,
        }],
    )
    .unwrap();
    dir
}

fn cfg(dir: &str, name: &str, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        codec: "slfac".into(),
        devices: 4,
        workers,
        rounds: 4,
        batches_per_round: 2,
        batch_size: BATCH,
        train_samples: 160,
        test_samples: 2 * BATCH,
        seed: 7,
        artifacts_dir: dir.into(),
        ..Default::default()
    }
}

struct RunResult {
    outcome: TrainOutcome,
    client: Vec<HostTensor>,
    server: Vec<HostTensor>,
}

/// Build a trainer, optionally resume from its checkpoint dir, optionally
/// stop after a round, run, and snapshot the final parameters.
fn run(cfg: ExperimentConfig, resume: bool, stop_after: Option<usize>) -> RunResult {
    cfg.validate().expect("config validates");
    let exec = ExecutorHandle::spawn_sim(&cfg.artifacts_dir, &["mnist".into()])
        .expect("sim executor");
    let mut trainer = Trainer::new(cfg, exec).expect("trainer");
    if resume {
        trainer.resume_latest().expect("resume");
    }
    trainer.set_stop_after(stop_after);
    let outcome = trainer.run().expect("run");
    RunResult {
        outcome,
        client: trainer.client_params(),
        server: trainer.server_params(),
    }
}

fn param_bits(params: &[HostTensor]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// CSV text with the trailing `wall_time_s` column stripped from every
/// line — the one column carrying host-clock noise.
fn csv_no_wall(csv: &str) -> String {
    csv.lines()
        .map(|l| &l[..l.rfind(',').unwrap()])
        .collect::<Vec<_>>()
        .join("\n")
}

fn assert_resume_matches(full: &RunResult, resumed: &RunResult, label: &str) {
    assert!(
        full.outcome.history.bit_eq(&resumed.outcome.history),
        "{label}: TrainingHistory diverged"
    );
    assert!(
        full.outcome.comm.bit_eq(&resumed.outcome.comm),
        "{label}: CommStats diverged: {:?} vs {:?}",
        full.outcome.comm,
        resumed.outcome.comm
    );
    assert_eq!(
        param_bits(&full.client),
        param_bits(&resumed.client),
        "{label}: client params diverged"
    );
    assert_eq!(
        param_bits(&full.server),
        param_bits(&resumed.server),
        "{label}: server params diverged"
    );
    assert_eq!(
        csv_no_wall(&full.outcome.history.to_csv()),
        csv_no_wall(&resumed.outcome.history.to_csv()),
        "{label}: CSV bytes diverged (wall column stripped)"
    );
}

#[test]
fn kill_and_resume_matches_uninterrupted_across_the_matrix() {
    let dir = sim_dir("matrix");
    for workers in [1usize, 4] {
        for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
            for fast_path in [true, false] {
                let label = format!(
                    "workers={workers} scheduler={} fast_path={fast_path}",
                    scheduler.name()
                );
                let mk = |ckpt: Option<&str>| {
                    let mut c = cfg(
                        &dir,
                        &format!("m_{workers}_{}_{fast_path}", scheduler.name()),
                        workers,
                    );
                    c.scheduler = scheduler;
                    c.compute_fast_path = fast_path;
                    if let Some(d) = ckpt {
                        c.checkpoint_every = 2;
                        c.checkpoint_dir = d.to_string();
                    }
                    c
                };
                // uninterrupted reference, checkpointing entirely off
                let full = run(mk(None), false, None);

                // interrupted at the round-2 boundary, then resumed: the
                // checkpoint keys never enter the fingerprint, so the
                // resumed run accepts the interrupted run's checkpoint
                let ckpt = format!("{dir}/ckpt_{workers}_{}_{fast_path}", scheduler.name());
                let cut = run(mk(Some(&ckpt)), false, Some(2));
                assert_eq!(
                    cut.outcome.history.rounds.len(),
                    2,
                    "{label}: interrupted run must stop after round 2"
                );
                assert!(
                    std::path::Path::new(&format!("{ckpt}/ckpt_round_00000002.bin")).exists(),
                    "{label}: round-2 checkpoint missing"
                );
                let resumed = run(mk(Some(&ckpt)), true, None);
                assert_eq!(resumed.outcome.history.rounds.len(), 4);
                assert_resume_matches(&full, &resumed, &label);
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_composes_with_fault_injection() {
    // checkpointing through an actively faulty run: the restored link
    // RNGs, retry accounting, and fault-plan purity must all line up so
    // the resumed half replays the exact fault sequence
    let dir = sim_dir("faulty");
    for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
        let label = format!("faulty resume, scheduler={}", scheduler.name());
        let mk = |ckpt: Option<&str>| {
            let mut c = cfg(&dir, &format!("f_{}", scheduler.name()), 4);
            c.scheduler = scheduler;
            c.codec = "tk-sl".into();
            c.fault = FaultConfig {
                loss_prob: 0.1,
                corrupt_prob: 0.05,
                crash_rate: 0.1,
                server_outage_s: 0.2,
                ..Default::default()
            };
            if let Some(d) = ckpt {
                c.checkpoint_every = 2;
                c.checkpoint_dir = d.to_string();
            }
            c
        };
        let full = run(mk(None), false, None);
        let ckpt = format!("{dir}/ckpt_f_{}", scheduler.name());
        run(mk(Some(&ckpt)), false, Some(2));
        let resumed = run(mk(Some(&ckpt)), true, None);
        assert_resume_matches(&full, &resumed, &label);
        // guard against vacuity: the fault layer must actually fire
        let activity: u64 = resumed
            .outcome
            .history
            .rounds
            .iter()
            .map(|m| m.retransmits + m.corrupt_payloads + m.lost_bytes)
            .sum();
        assert!(activity > 0, "{label}: fault layer never engaged");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_with_empty_dir_is_a_fresh_start() {
    let dir = sim_dir("fresh");
    let mk = |name: &str| {
        let mut c = cfg(&dir, name, 2);
        c.checkpoint_every = 2;
        c.checkpoint_dir = format!("{dir}/never_written_{name}");
        c
    };
    let fresh = run(mk("a"), false, None);
    // same config, resume over a directory that has no checkpoints (it
    // does not even exist): identical run, not an error
    let resumed = run(mk("a"), true, None);
    assert_resume_matches(&fresh, &resumed, "fresh-start resume");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_corrupt_and_foreign_files_fail_closed() {
    let dir = sim_dir("failclosed");
    let ckpt = format!("{dir}/ckpt");
    let mk = |seed: u64| {
        let mut c = cfg(&dir, "fc", 2);
        c.seed = seed;
        c.checkpoint_every = 2;
        c.checkpoint_dir = ckpt.clone();
        c
    };
    run(mk(7), false, Some(2));
    let path = format!("{ckpt}/ckpt_round_00000002.bin");
    let pristine = std::fs::read(&path).unwrap();

    let resume_err = |c: ExperimentConfig| -> String {
        let exec = ExecutorHandle::spawn_sim(&c.artifacts_dir, &["mnist".into()]).unwrap();
        let mut trainer = Trainer::new(c, exec).unwrap();
        format!("{:#}", trainer.resume_latest().unwrap_err())
    };

    // torn: the file ends before the length the header promises
    std::fs::write(&path, &pristine[..pristine.len() / 2]).unwrap();
    let err = resume_err(mk(7));
    assert!(err.contains("torn"), "torn file must be named: {err}");

    // corrupt: one flipped bit in the body trips the checksum
    let mut flipped = pristine.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x10;
    std::fs::write(&path, &flipped).unwrap();
    let err = resume_err(mk(7));
    assert!(
        err.contains("checksum") && err.contains("corrupt"),
        "corrupt file must be named: {err}"
    );

    // foreign config: a different seed produces a different fingerprint,
    // and the error names the differing key with both values
    std::fs::write(&path, &pristine).unwrap();
    let err = resume_err(mk(1234));
    assert!(
        err.contains("different config") && err.contains("seed"),
        "foreign-config rejection must name the key: {err}"
    );

    // not a checkpoint at all
    std::fs::write(&path, b"definitely not a checkpoint").unwrap();
    let err = resume_err(mk(7));
    assert!(
        err.contains("not a checkpoint file"),
        "bad magic must be named: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn retention_keeps_only_the_last_k_and_resumes_from_the_newest() {
    let dir = sim_dir("retention");
    let ckpt = format!("{dir}/ckpt");
    let mk = || {
        let mut c = cfg(&dir, "keep", 2);
        c.checkpoint_every = 1;
        c.checkpoint_dir = ckpt.clone();
        c
    };
    let full = run(mk(), false, None);
    let mut files: Vec<String> = std::fs::read_dir(&ckpt)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    files.sort();
    assert_eq!(
        files,
        [
            "ckpt_round_00000002.bin",
            "ckpt_round_00000003.bin",
            "ckpt_round_00000004.bin"
        ],
        "4 rounds at keep-last-3: round 1 pruned, no temp files left"
    );
    // resuming a *finished* run restores everything from the newest
    // checkpoint and re-executes zero rounds
    let resumed = run(mk(), true, None);
    assert_resume_matches(&full, &resumed, "resume-at-completion");
    let _ = std::fs::remove_dir_all(&dir);
}
