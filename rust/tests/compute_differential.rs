//! Differential bit-identity for the compute backend: the planned fast
//! path (blocked GEMM kernels + device-resident state + fused backward,
//! `compute_fast_path = true`, the default) must reproduce the reference
//! artifact-execute path **bit-for-bit** — kernel by kernel over
//! randomized shapes/seeds, and end-to-end over full training runs
//! (histories, comm stats, wire bytes, final parameters).
//!
//! Runs on the sim executor backend (pure Rust, manifest only), so it
//! needs no XLA runtime and always runs.

use slfac::config::{ExperimentConfig, SyncMode};
use slfac::coordinator::{TrainOutcome, Trainer};
use slfac::runtime::compute::{
    fwd_gemm, fwd_gemm_ref, gact_fast, gact_ref, grad_outer, grad_outer_ref, sgd_momentum,
    sgd_momentum_ref, sgd_momentum_tracked, softmax_xent_fused, softmax_xent_ref,
};
use slfac::runtime::{write_sim_manifest, ExecutorHandle, HostTensor, SimManifestSpec};
use slfac::testing::prop;

const BATCH: usize = 8;

// --- kernel level ---------------------------------------------------------

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

#[test]
fn blocked_kernels_match_reference_over_random_shapes() {
    prop("fast kernels == reference kernels", 80, |g| {
        let b = g.usize_in(1, 9);
        let i_dim = g.usize_in(1, 150);
        let j_dim = g.usize_in(1, 200);
        // ~1/8 exact zeros so the zero-skip branches are exercised on both
        // sides of the comparison
        let sparse = |n: usize, g: &mut slfac::testing::Gen| -> Vec<f32> {
            (0..n)
                .map(|_| {
                    if g.usize_in(0, 7) == 0 {
                        0.0
                    } else {
                        g.f32_in(-2.0, 2.0)
                    }
                })
                .collect()
        };
        let x = sparse(b * i_dim, g);
        let w = sparse(i_dim * j_dim, g);
        let want = fwd_gemm_ref(&x, &w, b, i_dim, j_dim);
        let mut got = vec![f32::NAN; b * j_dim]; // dirty output buffer
        fwd_gemm(&x, &w, b, i_dim, j_dim, &mut got);
        assert_eq!(bits(&got), bits(&want), "fwd {b}x{i_dim}x{j_dim}");

        let d = sparse(b * j_dim, g);
        let want = grad_outer_ref(&x, &d, b, i_dim, j_dim);
        let mut got = vec![f32::NAN; i_dim * j_dim];
        grad_outer(&x, &d, b, i_dim, j_dim, &mut got);
        assert_eq!(bits(&got), bits(&want), "grad {b}x{i_dim}x{j_dim}");

        // gact: treat i_dim as the feature width, j_dim-capped classes
        let classes = g.usize_in(1, 12);
        let dl = sparse(b * classes, g);
        let w_s = sparse(i_dim * classes, g);
        let mut w_s_t = vec![0.0f32; i_dim * classes];
        for r in 0..i_dim {
            for c in 0..classes {
                w_s_t[c * i_dim + r] = w_s[r * classes + c];
            }
        }
        let want = gact_ref(&dl, &w_s, b, i_dim, classes);
        let mut got = vec![f32::NAN; b * i_dim];
        gact_fast(&dl, &w_s_t, b, i_dim, classes, &mut got);
        assert_eq!(bits(&got), bits(&want), "gact {b}x{i_dim}x{classes}");
    });
}

#[test]
fn fused_softmax_and_sgd_match_reference_over_random_inputs() {
    prop("fused softmax/sgd == reference", 80, |g| {
        let b = g.usize_in(1, 10);
        let classes = g.usize_in(2, 12);
        let logits = g.normal_vec(b * classes);
        let labels: Vec<i32> = (0..b).map(|_| g.usize_in(0, classes - 1) as i32).collect();
        let (want_loss, want_correct, want_d) = softmax_xent_ref(&logits, &labels, b, classes);
        let mut exp = vec![f32::NAN; b * classes];
        let mut d = vec![f32::NAN; b * classes];
        let (loss, correct) = softmax_xent_fused(&logits, &labels, b, classes, &mut exp, &mut d);
        assert_eq!(loss.to_bits(), want_loss.to_bits());
        assert_eq!(correct, want_correct);
        assert_eq!(bits(&d), bits(&want_d));

        let rows = g.usize_in(1, 20);
        let cols = g.usize_in(1, 20);
        let n = rows * cols;
        let w0 = g.normal_vec(n);
        let m0 = g.normal_vec(n);
        let grad = g.normal_vec(n);
        let lr = g.f32_in(0.001, 0.5);
        let (want_w, want_m) = sgd_momentum_ref(&w0, &m0, &grad, lr);
        let (mut w1, mut m1) = (w0.clone(), m0.clone());
        sgd_momentum(&mut w1, &mut m1, &grad, lr);
        assert_eq!(bits(&w1), bits(&want_w));
        assert_eq!(bits(&m1), bits(&want_m));
        let (mut w2, mut m2) = (w0, m0);
        let mut wt = vec![f32::NAN; n];
        sgd_momentum_tracked(&mut w2, &mut m2, &grad, lr, &mut wt, rows, cols);
        assert_eq!(bits(&w2), bits(&want_w));
        assert_eq!(bits(&m2), bits(&want_m));
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(
                    wt[c * rows + r].to_bits(),
                    w2[r * cols + c].to_bits(),
                    "transpose drifted at ({r},{c})"
                );
            }
        }
    });
}

// --- trainer level --------------------------------------------------------

fn sim_dir(label: &str, act_channels: usize, act_hw: usize) -> String {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = format!(
        "{}/slfac_compdiff_{label}_{}_{}",
        std::env::temp_dir().display(),
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: BATCH,
            act_channels,
            act_hw,
        }],
    )
    .unwrap();
    dir
}

fn cfg(dir: &str, codec: &str, seed: u64, fast: bool) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("compdiff_{codec}_{seed}_{fast}"),
        codec: codec.into(),
        devices: 4,
        workers: 1,
        rounds: 2,
        batches_per_round: 2,
        batch_size: BATCH,
        train_samples: 160,
        test_samples: 2 * BATCH,
        seed,
        artifacts_dir: dir.into(),
        compute_fast_path: fast,
        ..Default::default()
    }
}

struct RunResult {
    outcome: TrainOutcome,
    client: Vec<HostTensor>,
    server: Vec<HostTensor>,
}

fn run(cfg: ExperimentConfig) -> RunResult {
    let exec = ExecutorHandle::spawn_sim(&cfg.artifacts_dir, &["mnist".into()])
        .expect("sim executor");
    let mut trainer = Trainer::new(cfg, exec).expect("trainer");
    let outcome = trainer.run().expect("run");
    RunResult {
        outcome,
        client: trainer.client_params(),
        server: trainer.server_params(),
    }
}

fn param_bits(params: &[HostTensor]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert!(
        a.outcome.history.bit_eq(&b.outcome.history),
        "{label}: TrainingHistory diverged"
    );
    assert!(
        a.outcome.comm.bit_eq(&b.outcome.comm),
        "{label}: CommStats diverged"
    );
    assert_eq!(
        param_bits(&a.client),
        param_bits(&b.client),
        "{label}: client params diverged"
    );
    assert_eq!(
        param_bits(&a.server),
        param_bits(&b.server),
        "{label}: server params diverged"
    );
}

#[test]
fn fast_compute_matches_reference_end_to_end() {
    // seeds × codecs (frequency-domain slfac exercises the resident DCT
    // path, identity the spatial one, tk-sl the randomized-codec RNG
    // threading) × both activation plane kinds (power-of-two 4×4 takes
    // the Lee DCT, 7×7 the planned matmul DCT)
    for &(act_c, act_hw) in &[(2usize, 4usize), (2, 7)] {
        let dir = sim_dir("e2e", act_c, act_hw);
        for &seed in &[7u64, 1234] {
            for codec in ["slfac", "identity", "tk-sl"] {
                let reference = run(cfg(&dir, codec, seed, false));
                let fast = run(cfg(&dir, codec, seed, true));
                assert_bit_identical(
                    &reference,
                    &fast,
                    &format!("plane {act_c}x{act_hw}x{act_hw} seed={seed} codec={codec}"),
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn fast_compute_matches_reference_in_sequential_mode() {
    // sequential SL shuttles client weights device→device: the resident
    // copy chain must reproduce the reference clone chain exactly
    let dir = sim_dir("seq", 2, 4);
    for &seed in &[7u64, 99] {
        let mk = |fast: bool| {
            let mut c = cfg(&dir, "slfac", seed, fast);
            c.sync = SyncMode::Sequential;
            c
        };
        let reference = run(mk(false));
        let fast = run(mk(true));
        assert_bit_identical(&reference, &fast, &format!("sequential seed={seed}"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fast_compute_matches_reference_with_raw_gradients() {
    // compress_gradients = false: the fast path stages the raw spatial
    // gradient in the device wire tensor (GradMsg::Stashed) — bytes and
    // math must still match the reference HostTensor path
    let dir = sim_dir("rawgrad", 2, 4);
    let mk = |fast: bool| {
        let mut c = cfg(&dir, "slfac", 21, fast);
        c.compress_gradients = false;
        c
    };
    let reference = run(mk(false));
    let fast = run(mk(true));
    assert_bit_identical(&reference, &fast, "raw gradients");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fast_compute_composes_with_sampling_and_straggler_policies() {
    // client sampling + async quorum over a heterogeneous fleet: devices
    // rejoin from the aggregate after sitting out — the resident slot
    // reload must match the reference clone-reset exactly
    use slfac::transport::{ClientSampling, SchedulerKind, StragglerPolicy};
    let dir = sim_dir("contention", 2, 4);
    let mk = |fast: bool| {
        let mut c = cfg(&dir, "slfac", 11, fast);
        c.scheduler = SchedulerKind::Async;
        c.profile = "wifi/lte".into();
        c.straggler = StragglerPolicy::Quorum { k: 2 };
        c.sampling = ClientSampling::Count(3);
        c.rounds = 3;
        c
    };
    let reference = run(mk(false));
    let fast = run(mk(true));
    assert_bit_identical(&reference, &fast, "sampled quorum");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resident_session_records_the_same_stats_surface() {
    // exec stats are wall-clock diagnostics (not part of bit_eq), but the
    // resident path must keep the per-artifact accounting comparable:
    // same keys, same execution counts as the artifact path
    let dir = sim_dir("stats", 2, 4);
    let reference = run(cfg(&dir, "slfac", 5, false));
    let fast = run(cfg(&dir, "slfac", 5, true));
    let counts = |o: &TrainOutcome| -> Vec<(String, u64)> {
        o.exec_stats
            .per_artifact
            .iter()
            .map(|(k, (n, _))| (k.clone(), *n))
            .collect()
    };
    assert_eq!(counts(&reference.outcome), counts(&fast.outcome));
    let _ = std::fs::remove_dir_all(&dir);
}
