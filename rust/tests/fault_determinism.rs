//! Fault-injection determinism and transparency pins.
//!
//! Four contracts from the fault subsystem's design:
//!
//! 1. **Inert knobs are invisible**: a config with every fault
//!    *probability* at zero (retry knobs may be tuned) is bit-identical
//!    to one with no fault keys at all — history, comm stats, final
//!    parameters, and the metrics CSV bytes.
//! 2. **Worker-count transparency**: a faulty run (loss + corruption +
//!    crashes + a server outage) reproduces the `workers = 1` run
//!    bit-for-bit at any worker count, on both schedulers. Fault draws
//!    are pure functions of `(seed, round, device, step, attempt)` and
//!    retry events ride the same `(sim_time, seq)` heap as everything
//!    else, so thread scheduling can never leak in.
//! 3. **Scheduler agreement**: with corruption + crashes only (no loss,
//!    no outage, one batch per round, fixed-rate codec, homogeneous
//!    fleet) sync and async rounds see identical arrival sequences, so
//!    the two schedulers agree bit-for-bit.
//! 4. **Blast-radius containment** (regression): a 16-device round with
//!    exactly one corrupted uplink completes with `corrupt_payloads == 1`,
//!    one retransmission, and — because the corrupted device re-delivers
//!    a clean payload before the barrier — learning metrics and final
//!    parameters bit-identical to the fault-free run; only byte/time
//!    accounting moves.
//!
//! Runs on the sim executor backend — no XLA, no artifacts.

use slfac::config::{ExperimentConfig, SyncMode};
use slfac::coordinator::{TrainOutcome, Trainer};
use slfac::runtime::{write_sim_manifest, ExecutorHandle, HostTensor, SimManifestSpec};
use slfac::transport::{FaultConfig, FaultPlan, SchedulerKind};

const BATCH: usize = 8;

fn sim_dir(label: &str) -> String {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = format!(
        "{}/slfac_fault_{label}_{}_{}",
        std::env::temp_dir().display(),
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: BATCH,
            act_channels: 2,
            act_hw: 4,
        }],
    )
    .unwrap();
    dir
}

fn cfg(dir: &str, codec: &str, seed: u64, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("fault_{codec}_{seed}_{workers}"),
        codec: codec.into(),
        devices: 4,
        workers,
        sync: SyncMode::ParallelFedAvg,
        rounds: 2,
        batches_per_round: 2,
        batch_size: BATCH,
        train_samples: 160,
        test_samples: 2 * BATCH,
        seed,
        artifacts_dir: dir.into(),
        ..Default::default()
    }
}

struct RunResult {
    outcome: TrainOutcome,
    client: Vec<HostTensor>,
    server: Vec<HostTensor>,
}

fn run(cfg: ExperimentConfig) -> RunResult {
    cfg.validate().expect("config validates");
    let exec = ExecutorHandle::spawn_sim(&cfg.artifacts_dir, &["mnist".into()])
        .expect("sim executor");
    let mut trainer = Trainer::new(cfg, exec).expect("trainer");
    let outcome = trainer.run().expect("run");
    RunResult {
        outcome,
        client: trainer.client_params(),
        server: trainer.server_params(),
    }
}

fn param_bits(params: &[HostTensor]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert!(
        a.outcome.history.bit_eq(&b.outcome.history),
        "{label}: TrainingHistory diverged"
    );
    assert!(
        a.outcome.comm.bit_eq(&b.outcome.comm),
        "{label}: CommStats diverged: {:?} vs {:?}",
        a.outcome.comm,
        b.outcome.comm
    );
    assert_eq!(
        param_bits(&a.client),
        param_bits(&b.client),
        "{label}: client params diverged"
    );
    assert_eq!(
        param_bits(&a.server),
        param_bits(&b.server),
        "{label}: server params diverged"
    );
}

#[test]
fn inert_fault_knobs_match_absent_knobs_bitwise() {
    // zero probabilities = the fault layer never engages: the legacy
    // scheduler paths run, no fault RNG is drawn, and the metrics CSV
    // keeps its historical 14-column shape byte-for-byte
    let dir = sim_dir("inert");
    for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
        let mk = |tuned: bool| {
            let mut c = cfg(&dir, "slfac", 7, 2);
            c.name = format!("inert_{}_{tuned}", scheduler.name());
            c.scheduler = scheduler;
            if tuned {
                // retry knobs without any probability: still inert
                c.fault.max_retries = 7;
                c.fault.retry_base_s = 0.123;
            }
            c
        };
        let absent = run(mk(false));
        let inert = run(mk(true));
        assert_bit_identical(
            &absent,
            &inert,
            &format!("inert knobs, scheduler={}", scheduler.name()),
        );
        let csv_a = absent.outcome.history.to_csv();
        let csv_b = inert.outcome.history.to_csv();
        assert_eq!(csv_a, csv_b, "CSV bytes must match");
        assert!(
            !csv_a.contains("retransmits"),
            "fault-free CSV must keep the historical columns"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn faulty_runs_are_bit_identical_across_worker_counts() {
    // the full fault menu at once — message loss, payload corruption,
    // device crashes, a server outage — on both schedulers: workers = 4
    // and workers = 0 reproduce workers = 1 exactly
    let dir = sim_dir("workers");
    for &seed in &[7u64, 1234] {
        for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
            let mk = |workers: usize| {
                let mut c = cfg(&dir, "tk-sl", seed, workers);
                c.name = format!("fworkers_{}_{seed}_{workers}", scheduler.name());
                c.scheduler = scheduler;
                c.fault = FaultConfig {
                    loss_prob: 0.1,
                    corrupt_prob: 0.05,
                    crash_rate: 0.1,
                    server_outage_s: 0.2,
                    ..Default::default()
                };
                c
            };
            let reference = run(mk(1));
            for workers in [4usize, 0] {
                let got = run(mk(workers));
                assert_bit_identical(
                    &reference,
                    &got,
                    &format!(
                        "faulty seed={seed} scheduler={} workers={workers}",
                        scheduler.name()
                    ),
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn faulty_sync_and_async_agree_bitwise() {
    // one batch per round + fixed-rate codec + homogeneous fleet + no
    // loss/outage: both schedulers see the same arrival sequence (ties
    // resolve by push order, retransmissions re-arrive at the same
    // instants), so histories, comm stats, and parameters all match.
    // max_retries is raised so retry exhaustion — the one case where the
    // schedulers' sim-time accounting legitimately differs — cannot occur
    // (it would need 9 consecutive corrupt verdicts at p = 0.3).
    let dir = sim_dir("sched_agree");
    let mk = |scheduler: SchedulerKind| {
        let mut c = cfg(&dir, "identity", 13, 2);
        c.name = format!("fagree_{}", scheduler.name());
        c.devices = 8;
        c.train_samples = 320;
        c.batches_per_round = 1;
        c.scheduler = scheduler;
        c.fault = FaultConfig {
            corrupt_prob: 0.3,
            crash_rate: 0.25,
            max_retries: 8,
            ..Default::default()
        };
        c
    };
    let sync = run(mk(SchedulerKind::Sync));
    let asy = run(mk(SchedulerKind::Async));
    assert_bit_identical(&sync, &asy, "faulty sync vs async");
    // guard against vacuity: this seed must actually exercise the layer
    let activity: u64 = sync
        .outcome
        .history
        .rounds
        .iter()
        .map(|m| m.retransmits + m.corrupt_payloads + m.dropped_devices as u64)
        .sum();
    assert!(activity > 0, "seed 13 produced a fault-free run");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn one_corrupted_uplink_leaves_other_devices_untouched() {
    // Find a seed where in round 0 exactly one device — the last one, so
    // the retransmission does not reorder the barrier's serve sequence —
    // draws a corrupt verdict at attempt 0 and a clean one at attempt 1.
    let devices = 16usize;
    let fc = FaultConfig {
        corrupt_prob: 1.0 / devices as f64,
        ..Default::default()
    };
    let seed = (0..50_000u64)
        .find(|&s| {
            let plan = FaultPlan::new(fc, s, 0);
            (0..devices).all(|d| plan.uplink_corrupt(d, 0, 0) == (d == devices - 1))
                && !plan.uplink_corrupt(devices - 1, 0, 1)
        })
        .expect("no seed with exactly one corrupted uplink in 50k candidates");

    let dir = sim_dir("blast");
    let mk = |faulty: bool| {
        let mut c = cfg(&dir, "identity", seed, 2);
        c.name = format!("fblast_{faulty}");
        c.devices = devices;
        c.train_samples = devices * 2 * BATCH;
        c.rounds = 1;
        c.batches_per_round = 1;
        if faulty {
            c.fault = fc;
        }
        c
    };
    let clean = run(mk(false));
    let faulty = run(mk(true));

    let cm = &clean.outcome.history.rounds[0];
    let fm = &faulty.outcome.history.rounds[0];
    assert_eq!(fm.corrupt_payloads, 1, "exactly one corrupted payload");
    assert_eq!(fm.retransmits, 1, "one retransmission");
    assert_eq!(fm.lost_bytes, 0);
    assert_eq!(fm.dropped_devices, 0, "the round completes for everyone");
    assert_eq!(fm.sampled_devices, devices);

    // the retransmitted payload is the clean one, so training math — and
    // the other 15 devices' contributions in particular — is untouched
    assert_eq!(fm.train_loss.to_bits(), cm.train_loss.to_bits());
    assert_eq!(fm.train_acc.to_bits(), cm.train_acc.to_bits());
    assert_eq!(fm.test_loss.to_bits(), cm.test_loss.to_bits());
    assert_eq!(fm.test_acc.to_bits(), cm.test_acc.to_bits());
    assert_eq!(param_bits(&clean.client), param_bits(&faulty.client));
    assert_eq!(param_bits(&clean.server), param_bits(&faulty.server));

    // only accounting moves: the retransmission re-charges its bytes and
    // the backoff delays the barrier
    assert!(
        fm.uplink_bytes > cm.uplink_bytes,
        "retransmitted bytes must be charged: {} vs {}",
        fm.uplink_bytes,
        cm.uplink_bytes
    );
    assert_eq!(fm.downlink_bytes, cm.downlink_bytes);
    assert!(
        fm.sim_time_s > cm.sim_time_s,
        "backoff must lengthen the round: {} vs {}",
        fm.sim_time_s,
        cm.sim_time_s
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn all_devices_exceeding_max_retries_skips_the_round_without_nan() {
    // corrupt_prob = 1.0 + max_retries = 1: every uplink attempt on every
    // device is corrupt, so every device exhausts its retries and is
    // dropped — the total FedAvg weight is zero. The regression this pins:
    // the aggregate (and momenta) must carry forward unchanged instead of
    // dividing to NaN, every recorded metric must stay finite, and the
    // round must be recorded as skipped.
    let dir = sim_dir("alldrop");
    for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
        let mk = || {
            let mut c = cfg(&dir, "identity", 7, 2);
            c.name = format!("falldrop_{}", scheduler.name());
            c.scheduler = scheduler;
            c.fault = FaultConfig {
                corrupt_prob: 1.0,
                max_retries: 1,
                ..Default::default()
            };
            c
        };
        // initial parameters from an identical trainer that never ran
        let c0 = mk();
        let exec = ExecutorHandle::spawn_sim(&c0.artifacts_dir, &["mnist".into()]).unwrap();
        let untouched = Trainer::new(c0, exec).unwrap();
        let init_client = param_bits(&untouched.client_params());
        let init_server = param_bits(&untouched.server_params());

        let got = run(mk());
        let label = format!("all-dropped, scheduler={}", scheduler.name());
        for m in &got.outcome.history.rounds {
            assert!(m.skipped, "{label}: round {} must be skipped", m.round);
            assert_eq!(
                m.dropped_devices as usize, 4,
                "{label}: every device must be dropped"
            );
            for (v, what) in [
                (m.train_loss, "train_loss"),
                (m.train_acc, "train_acc"),
                (m.test_loss, "test_loss"),
                (m.test_acc, "test_acc"),
                (m.sim_time_s, "sim_time_s"),
            ] {
                assert!(v.is_finite(), "{label}: {what} is not finite: {v}");
            }
        }
        assert_eq!(
            param_bits(&got.client),
            init_client,
            "{label}: client aggregate must carry forward unchanged"
        );
        assert_eq!(
            param_bits(&got.server),
            init_server,
            "{label}: server params must carry forward unchanged"
        );
        // the skipped flag reaches the CSV as its own column
        let csv = got.outcome.history.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(
            header.contains(",skipped,"),
            "{label}: skipped column missing from {header}"
        );
        for row in csv.lines().skip(1) {
            let cols: Vec<&str> = row.split(',').collect();
            assert_eq!(cols[cols.len() - 2], "1", "{label}: skipped flag not set in {row}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn faulty_repeat_runs_are_self_consistent() {
    // same faulty config run twice: wall-clock noise must not leak into
    // any result (fault draws are seed-pure, not time-seeded)
    let dir = sim_dir("repeat");
    let mk = || {
        let mut c = cfg(&dir, "slfac", 42, 4);
        c.scheduler = SchedulerKind::Async;
        c.fault = FaultConfig {
            loss_prob: 0.15,
            corrupt_prob: 0.1,
            ..Default::default()
        };
        c
    };
    let a = run(mk());
    let b = run(mk());
    assert_bit_identical(&a, &b, "repeat faulty async");
    let _ = std::fs::remove_dir_all(&dir);
}
