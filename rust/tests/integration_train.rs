//! Full-system integration: the Trainer over real artifacts — one tiny run
//! per scenario, asserting learning progress and communication accounting.
//! Skipped when artifacts are missing.

use slfac::config::{ExperimentConfig, Partition, SyncMode};
use slfac::coordinator::Trainer;
use slfac::runtime::ExecutorHandle;
use std::sync::{Mutex, OnceLock};

fn artifacts_root() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn executor() -> Option<&'static Mutex<ExecutorHandle>> {
    static EXEC: OnceLock<Option<Mutex<ExecutorHandle>>> = OnceLock::new();
    EXEC.get_or_init(|| {
        if !std::path::Path::new(&format!("{}/manifest.json", artifacts_root())).exists() {
            eprintln!("SKIP: artifacts missing — run `make artifacts`");
            return None;
        }
        Some(Mutex::new(
            ExecutorHandle::spawn(artifacts_root(), &["mnist".to_string()])
                .expect("executor spawn"),
        ))
    })
    .as_ref()
}

fn tiny_cfg(codec: &str) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("it_{codec}"),
        codec: codec.into(),
        train_samples: 600,
        test_samples: 64,
        devices: 3,
        rounds: 2,
        batches_per_round: 4,
        artifacts_dir: artifacts_root().into(),
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn slfac_codec_trains_end_to_end() {
    let Some(exec) = executor() else { return };
    let exec = exec.lock().unwrap().clone();
    let mut t = Trainer::new(tiny_cfg("slfac"), exec).unwrap();
    let out = t.run().unwrap();
    assert_eq!(out.history.rounds.len(), 2);
    let r1 = &out.history.rounds[0];
    let r2 = &out.history.rounds[1];
    assert!(r2.train_loss < r1.train_loss, "loss must drop");
    assert!(r2.test_acc > 0.2, "better than chance: {}", r2.test_acc);
    // bytes were charged both ways
    assert!(r1.uplink_bytes > 0 && r1.downlink_bytes > 0);
    // slfac compresses well below fp32 (raw act = 32*16*14*14*4 per batch)
    let raw_per_round = (32 * 16 * 14 * 14 * 4) as u64 * 4 * 3; // batches*devices
    assert!(r1.uplink_bytes < raw_per_round / 2);
    assert!(out.exec_stats.total_execs() > 0);
}

#[test]
fn sequential_mode_also_learns() {
    let Some(exec) = executor() else { return };
    let exec = exec.lock().unwrap().clone();
    let mut cfg = tiny_cfg("slfac");
    cfg.sync = SyncMode::Sequential;
    let mut t = Trainer::new(cfg, exec).unwrap();
    let out = t.run().unwrap();
    let last = out.history.rounds.last().unwrap();
    assert!(last.train_loss < 2.3);
    assert!(last.test_acc > 0.2);
}

#[test]
fn noniid_partition_runs_and_accounts() {
    let Some(exec) = executor() else { return };
    let exec = exec.lock().unwrap().clone();
    let mut cfg = tiny_cfg("pq-sl");
    cfg.partition = Partition::Dirichlet(0.5);
    let mut t = Trainer::new(cfg, exec).unwrap();
    let out = t.run().unwrap();
    assert_eq!(out.history.rounds.len(), 2);
    assert!(out.comm.total_bytes() > 0);
    assert!(out.comm.makespan_s > 0.0);
}

#[test]
fn deterministic_given_seed() {
    let Some(exec) = executor() else { return };
    let exec1 = exec.lock().unwrap().clone();
    let exec2 = exec1.clone();
    let mut cfg = tiny_cfg("slfac");
    cfg.rounds = 1;
    let out1 = Trainer::new(cfg.clone(), exec1).unwrap().run().unwrap();
    let out2 = Trainer::new(cfg, exec2).unwrap().run().unwrap();
    let (a, b) = (&out1.history.rounds[0], &out2.history.rounds[0]);
    assert_eq!(a.uplink_bytes, b.uplink_bytes);
    assert!((a.train_loss - b.train_loss).abs() < 1e-9);
    assert!((a.test_acc - b.test_acc).abs() < 1e-9);
}

#[test]
fn gradient_compression_toggle_halves_downlink() {
    let Some(exec) = executor() else { return };
    let exec1 = exec.lock().unwrap().clone();
    let exec2 = exec1.clone();
    let mut on = tiny_cfg("slfac");
    on.rounds = 1;
    let mut off = on.clone();
    off.compress_gradients = false;
    let o1 = Trainer::new(on, exec1).unwrap().run().unwrap();
    let o2 = Trainer::new(off, exec2).unwrap().run().unwrap();
    assert!(
        o1.history.rounds[0].downlink_bytes * 2 < o2.history.rounds[0].downlink_bytes,
        "compressed downlink {} vs raw {}",
        o1.history.rounds[0].downlink_bytes,
        o2.history.rounds[0].downlink_bytes
    );
}
