//! Fleet-scale equivalence: the cohort-compressed control flow and the
//! downlink contention model are **accounting changes, not semantic
//! ones**. Three contracts, all bit-for-bit:
//!
//! 1. a Trainer round with `cohorts > 0` reproduces the per-device round
//!    exactly (history, comm stats, final parameters) on a heterogeneous
//!    fleet, for both schedulers and every straggler policy;
//! 2. one device on a shared downlink pipe of its private capacity costs
//!    exactly what the private path costs (the fair-share fluid model
//!    degenerates to the private link when there is no contention);
//! 3. a 10k-device round over [`FleetOps`] completes every device in
//!    bounded wall time — the tier-1 smoke for the million-device bench.
//!
//! Runs on the sim executor backend (pure Rust, manifest only), so this
//! test needs no XLA runtime and no `make artifacts` — it always runs.

use slfac::config::{ExperimentConfig, SyncMode};
use slfac::coordinator::{TrainOutcome, Trainer};
use slfac::runtime::{write_sim_manifest, ExecutorHandle, HostTensor, SimManifestSpec};
use slfac::transport::fleet::{FleetCohort, FleetOps};
use slfac::transport::{
    AsyncEventScheduler, DownlinkMode, RoundScheduler, SchedulerKind, StragglerPolicy,
    SyncEventScheduler, UplinkMode,
};

const BATCH: usize = 8;

fn sim_dir(label: &str) -> String {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = format!(
        "{}/slfac_fleet_{label}_{}_{}",
        std::env::temp_dir().display(),
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    );
    write_sim_manifest(
        &dir,
        &[SimManifestSpec {
            preset: "mnist".into(),
            batch_size: BATCH,
            act_channels: 2,
            act_hw: 4,
        }],
    )
    .unwrap();
    dir
}

fn fleet_cfg(dir: &str, name: &str, devices: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        codec: "slfac".into(),
        devices,
        workers: 2,
        sync: SyncMode::ParallelFedAvg,
        rounds: 2,
        batches_per_round: 2,
        batch_size: BATCH,
        train_samples: devices * 16,
        test_samples: 2 * BATCH,
        seed: 23,
        artifacts_dir: dir.into(),
        ..Default::default()
    }
}

struct RunResult {
    outcome: TrainOutcome,
    client: Vec<HostTensor>,
    server: Vec<HostTensor>,
}

fn run(cfg: ExperimentConfig) -> RunResult {
    let exec = ExecutorHandle::spawn_sim(&cfg.artifacts_dir, &["mnist".into()])
        .expect("sim executor");
    let mut trainer = Trainer::new(cfg, exec).expect("trainer");
    let outcome = trainer.run().expect("run");
    RunResult {
        outcome,
        client: trainer.client_params(),
        server: trainer.server_params(),
    }
}

fn param_bits(params: &[HostTensor]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert!(
        a.outcome.history.bit_eq(&b.outcome.history),
        "{label}: TrainingHistory diverged"
    );
    assert!(
        a.outcome.comm.bit_eq(&b.outcome.comm),
        "{label}: CommStats diverged: {:?} vs {:?}",
        a.outcome.comm,
        b.outcome.comm
    );
    assert_eq!(
        param_bits(&a.client),
        param_bits(&b.client),
        "{label}: client params diverged"
    );
    assert_eq!(
        param_bits(&a.server),
        param_bits(&b.server),
        "{label}: server params diverged"
    );
}

#[test]
fn cohort_rounds_match_per_device_rounds_bitwise() {
    // 64 heterogeneous devices, cohorts = 4 vs cohorts = 0: the cohort
    // control flow groups event-queue work by identical arrival times —
    // it must never change what happens, only how it is scheduled.
    // Server service time is on so the queue arithmetic (the subtlest
    // part of the fold) is exercised too.
    let dir = sim_dir("cohort");
    let cases: [(SchedulerKind, StragglerPolicy); 4] = [
        (SchedulerKind::Sync, StragglerPolicy::WaitAll),
        (SchedulerKind::Async, StragglerPolicy::WaitAll),
        (SchedulerKind::Async, StragglerPolicy::DeadlineDrop { deadline_s: 0.05 }),
        (SchedulerKind::Async, StragglerPolicy::Quorum { k: 48 }),
    ];
    for (scheduler, policy) in cases {
        let mk = |cohorts: usize| {
            let mut c = fleet_cfg(
                &dir,
                &format!("fleet_{}_{}_{cohorts}", scheduler.name(), policy.name()),
                64,
            );
            c.scheduler = scheduler;
            c.straggler = policy;
            c.profile = "wifi/lte".into();
            c.server_service_s = 0.0005;
            c.cohorts = cohorts;
            c
        };
        let per_device = run(mk(0));
        let cohort = run(mk(4));
        assert_bit_identical(
            &per_device,
            &cohort,
            &format!("scheduler={} policy={}", scheduler.name(), policy.name()),
        );
        // non-vacuous: bytes actually flowed
        assert!(per_device.outcome.comm.uplink_bytes > 0);
        assert!(per_device.outcome.comm.downlink_bytes > 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cohorts_fall_back_cleanly_under_shared_pipes() {
    // cohorts compose with a shared uplink by falling back to the
    // per-device event path — results must be bit-identical to the same
    // shared-uplink run with cohorts off, i.e. the knob is inert there
    let dir = sim_dir("fallback");
    let mk = |cohorts: usize| {
        let mut c = fleet_cfg(&dir, &format!("fallback_{cohorts}"), 8);
        c.scheduler = SchedulerKind::Async;
        c.uplink = UplinkMode::Shared;
        c.shared_uplink_bps = Some(20e6);
        c.cohorts = cohorts;
        c
    };
    assert_bit_identical(&run(mk(0)), &run(mk(4)), "shared uplink, cohorts 0 vs 4");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_downlink_single_device_matches_private_bitwise() {
    // the downlink contention acceptance edge, symmetric to the uplink
    // one: one device on a shared server-egress pipe of the same capacity
    // as its private downlink costs bit-for-bit the same — history, comm
    // stats, and parameters
    let dir = sim_dir("down_single");
    for scheduler in [SchedulerKind::Sync, SchedulerKind::Async] {
        let mk = |downlink: DownlinkMode| {
            let mut c = fleet_cfg(
                &dir,
                &format!("down_single_{}_{}", scheduler.name(), downlink.name()),
                1,
            );
            c.scheduler = scheduler;
            c.downlink = downlink;
            c
        };
        let private = run(mk(DownlinkMode::Private));
        let shared = run(mk(DownlinkMode::Shared));
        assert_bit_identical(
            &private,
            &shared,
            &format!("single device shared-vs-private downlink, scheduler={}", scheduler.name()),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_downlink_contention_stretches_rounds_but_not_bytes() {
    // 4 devices behind one server-egress pipe vs 4 private downlinks of
    // the same rate: identical bytes, strictly longer simulated rounds
    let dir = sim_dir("down_slow");
    let mk = |downlink: DownlinkMode| {
        let mut c = fleet_cfg(&dir, &format!("down_slow_{}", downlink.name()), 4);
        c.codec = "identity".into();
        c.scheduler = SchedulerKind::Async;
        c.downlink = downlink;
        // serialization-dominated regime so the fair-share split shows
        c.link.downlink_bps = 1e6;
        c.link.latency_s = 0.0;
        c
    };
    let private = run(mk(DownlinkMode::Private));
    let shared = run(mk(DownlinkMode::Shared));
    assert_eq!(
        private.outcome.comm.downlink_bytes, shared.outcome.comm.downlink_bytes,
        "contention must not change what is transmitted"
    );
    assert_eq!(
        param_bits(&private.client),
        param_bits(&shared.client),
        "contention is timing-only: training math identical"
    );
    for (p, s) in private
        .outcome
        .history
        .rounds
        .iter()
        .zip(&shared.outcome.history.rounds)
    {
        assert!(
            s.sim_time_s > 1.5 * p.sim_time_s,
            "round {}: shared {} should be well beyond private {}",
            p.round,
            s.sim_time_s,
            p.sim_time_s
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ten_thousand_device_round_completes_quickly() {
    // tier-1 smoke for the fleet bench: a 10k-device cohort round over
    // pure-bookkeeping ops finishes in bounded time on both schedulers,
    // completes every device, and its byte accounting is exact
    const DEVICES: usize = 10_000;
    const STEPS: usize = 2;
    let profiles = vec![
        FleetCohort::default(),
        FleetCohort {
            compute_s: 0.006,
            uplink_cost_s: 0.045,
            downlink_s: 0.020,
            uplink_bytes: 12_000,
            downlink_bytes: 6_000,
        },
    ];
    let schedulers: [(&str, Box<dyn RoundScheduler>); 2] = [
        ("sync", Box::new(SyncEventScheduler::new())),
        (
            "async/wait-all",
            Box::new(AsyncEventScheduler::new(StragglerPolicy::WaitAll)),
        ),
    ];
    let start = std::time::Instant::now();
    for (label, sched) in &schedulers {
        let mut ops = FleetOps::new(DEVICES, STEPS, profiles.clone());
        ops.set_cohorts(profiles.len());
        ops.set_server_service_s(1e-6);
        let report = sched.run_round(&mut ops).unwrap();
        assert_eq!(report.completed, DEVICES, "{label}: every device completes");
        assert_eq!(report.dropped(), 0, "{label}: wait-all never drops");
        assert!(report.sim_round_s > 0.0, "{label}: simulated time advanced");
        let (fanouts, steps, fanins, cancelled, up, down) = ops.counters();
        let n = (DEVICES * STEPS) as u64;
        assert_eq!((fanouts, steps, fanins, cancelled), (n, n, n, 0), "{label}");
        assert_eq!(up, n * 12_000, "{label}: uplink bytes");
        assert_eq!(down, n * 6_000, "{label}: downlink bytes");
    }
    // pure bookkeeping: a 10k round is milliseconds; 60 s leaves two
    // orders of magnitude of headroom on a loaded CI box
    assert!(
        start.elapsed().as_secs() < 60,
        "10k-device rounds took {:?} — fleet path has an O(n^2) regression",
        start.elapsed()
    );
}
