//! Fig. 4 reproduction: the two ablation rows.
//!
//! * row 1 (`--part afd`): AFD vs magnitude-based and STD-based spatial
//!   feature selection (same bit machinery, different "what to keep").
//! * row 2 (`--part fqc`): FQC vs PowerQuant vs EasyQuant vs flat
//!   AFD+uniform bits (same AFD front end where applicable, different
//!   quantizer).
//!
//! ```text
//! cargo run --release --example fig4_ablation -- \
//!     [--part afd|fqc|both] [--partitions iid,non-iid] [--rounds N]
//! ```

use slfac::cli::Command;
use slfac::config::{ExperimentConfig, Partition};
use slfac::experiments::{print_convergence_table, run_suite, with_codec};

fn main() -> anyhow::Result<()> {
    slfac::logging::init_from_env();
    let cmd = Command::new("fig4_ablation", "paper Fig. 4 reproduction")
        .opt("part", "WHICH", "afd | fqc | both", Some("both"))
        .opt("partitions", "LIST", "iid,non-iid", Some("iid,non-iid"))
        .opt("rounds", "N", "override rounds (0 = config default)", Some("0"));
    let m = match cmd.parse() {
        Ok(m) => m,
        Err(slfac::cli::CliError::Help(h)) => {
            println!("{h}");
            return Ok(());
        }
        Err(slfac::cli::CliError::Bad(e)) => anyhow::bail!(e),
    };
    let part = m.req("part").map_err(anyhow::Error::msg)?.to_string();
    let partitions: Vec<&str> = m.req("partitions").map_err(anyhow::Error::msg)?.split(',').collect();
    let rounds_override: usize = m.get_parsed("rounds").map_err(anyhow::Error::msg)?.unwrap_or(0);

    let rows: Vec<(&str, Vec<&str>)> = match part.as_str() {
        "afd" => vec![("AFD ablation (Fig. 4 row 1)", vec!["slfac", "magnitude", "std"])],
        "fqc" => vec![(
            "FQC ablation (Fig. 4 row 2)",
            vec!["slfac", "pq-sl", "easyquant", "afd-uniform"],
        )],
        _ => vec![
            ("AFD ablation (Fig. 4 row 1)", vec!["slfac", "magnitude", "std"]),
            (
                "FQC ablation (Fig. 4 row 2)",
                vec!["slfac", "pq-sl", "easyquant", "afd-uniform"],
            ),
        ],
    };

    for (title, codecs) in rows {
        for partition in &partitions {
            let cfg_name = if *partition == "iid" { "mnist_iid" } else { "mnist_noniid" };
            let mut base = ExperimentConfig::load(&format!("configs/{cfg_name}.json"))?;
            base.partition = if *partition == "iid" {
                Partition::Iid
            } else {
                Partition::Dirichlet(0.5)
            };
            base.name = format!("fig4_{}_{}", part, cfg_name);
            if rounds_override > 0 {
                base.rounds = rounds_override;
            }
            let variants: Vec<ExperimentConfig> =
                codecs.iter().map(|c| with_codec(&base, c)).collect();
            let runs = run_suite(variants)?;
            print_convergence_table(&format!("{title}: MNIST / {partition}"), &runs);
        }
    }
    Ok(())
}
