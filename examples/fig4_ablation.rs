//! Fig. 4 reproduction: the two ablation rows.
//!
//! * row 1 (`--part afd`, `configs/sweeps/fig4_afd.json`): AFD vs
//!   magnitude-based and STD-based spatial feature selection (same bit
//!   machinery, different "what to keep").
//! * row 2 (`--part fqc`, `configs/sweeps/fig4_fqc.json`): FQC vs
//!   PowerQuant vs EasyQuant vs flat AFD+uniform bits (same AFD front end
//!   where applicable, different quantizer).
//!
//! Each row is its own sweep spec (partition × codec, byte-parity
//! calibration on the codec axis), so each checkpoints and resumes
//! independently:
//!
//! ```text
//! cargo run --release --example fig4_ablation -- [--part afd|fqc|both]
//! # equivalently: slfac sweep run --spec configs/sweeps/fig4_afd.json
//! #               slfac sweep run --spec configs/sweeps/fig4_fqc.json
//! ```

use slfac::cli::Command;
use slfac::experiments::print_sweep_tables;
use slfac::sweep::{run_sweep, SweepOptions, SweepSpec};

fn main() -> anyhow::Result<()> {
    slfac::logging::init_from_env();
    let cmd = Command::new("fig4_ablation", "paper Fig. 4 reproduction")
        .opt("part", "WHICH", "afd | fqc | both", Some("both"))
        .opt(
            "afd-spec",
            "PATH",
            "row-1 sweep spec",
            Some("configs/sweeps/fig4_afd.json"),
        )
        .opt(
            "fqc-spec",
            "PATH",
            "row-2 sweep spec",
            Some("configs/sweeps/fig4_fqc.json"),
        )
        .opt("workers", "N", "concurrent runs (0 = auto)", None)
        .opt("out-dir", "DIR", "results root", Some("results"));
    let m = match cmd.parse() {
        Ok(m) => m,
        Err(slfac::cli::CliError::Help(h)) => {
            println!("{h}");
            return Ok(());
        }
        Err(slfac::cli::CliError::Bad(e)) => anyhow::bail!(e),
    };
    let part = m.req("part").map_err(anyhow::Error::msg)?.to_string();
    let rows: Vec<(&str, &str)> = match part.as_str() {
        "afd" => vec![("AFD ablation (Fig. 4 row 1)", "afd-spec")],
        "fqc" => vec![("FQC ablation (Fig. 4 row 2)", "fqc-spec")],
        "both" => vec![
            ("AFD ablation (Fig. 4 row 1)", "afd-spec"),
            ("FQC ablation (Fig. 4 row 2)", "fqc-spec"),
        ],
        other => anyhow::bail!("--part must be afd | fqc | both, got '{other}'"),
    };
    let opts = SweepOptions {
        workers: m.get_parsed("workers").map_err(anyhow::Error::msg)?,
        out_dir: m.req("out-dir").map_err(anyhow::Error::msg)?.to_string(),
        ..Default::default()
    };
    for (title, spec_opt) in rows {
        let spec = SweepSpec::load(m.req(spec_opt).map_err(anyhow::Error::msg)?)?;
        let outcome = run_sweep(&spec, &opts)?;
        print_sweep_tables(title, &outcome.results);
        println!(
            "\n{} of {} runs journaled; report -> {}",
            outcome.completed, outcome.grid, outcome.report_path
        );
    }
    Ok(())
}
