//! Fig. 2 reproduction: test accuracy vs communication rounds for SL-FAC
//! against PQ-SL, TK-SL and FC-SL, on both datasets, IID and non-IID.
//!
//! ```text
//! cargo run --release --example fig2_convergence -- \
//!     [--datasets mnist,ham] [--partitions iid,non-iid] [--rounds N] [--codecs ...]
//! ```
//!
//! Writes one CSV per (setting, codec) under results/ and prints the
//! paper-style convergence grids. Expect ~45 s per (codec, setting) at the
//! default 15 rounds on a laptop-class CPU.

use slfac::cli::Command;
use slfac::config::{ExperimentConfig, Partition};
use slfac::experiments::{print_convergence_table, run_suite, with_codec};

fn main() -> anyhow::Result<()> {
    slfac::logging::init_from_env();
    let cmd = Command::new("fig2_convergence", "paper Fig. 2 reproduction")
        .opt("datasets", "LIST", "comma list: mnist,ham", Some("mnist,ham"))
        .opt("partitions", "LIST", "comma list: iid,non-iid", Some("iid,non-iid"))
        .opt("codecs", "LIST", "comma list", Some("slfac,pq-sl,tk-sl,fc-sl"))
        .opt("rounds", "N", "override rounds (0 = config default)", Some("0"));
    let m = match cmd.parse() {
        Ok(m) => m,
        Err(slfac::cli::CliError::Help(h)) => {
            println!("{h}");
            return Ok(());
        }
        Err(slfac::cli::CliError::Bad(e)) => anyhow::bail!(e),
    };
    let datasets: Vec<&str> = m.req("datasets").map_err(anyhow::Error::msg)?.split(',').collect();
    let partitions: Vec<&str> = m.req("partitions").map_err(anyhow::Error::msg)?.split(',').collect();
    let codecs: Vec<String> = m
        .req("codecs")
        .map_err(anyhow::Error::msg)?
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let rounds_override: usize = m.get_parsed("rounds").map_err(anyhow::Error::msg)?.unwrap_or(0);

    for dataset in &datasets {
        for partition in &partitions {
            let cfg_name = format!(
                "{}_{}",
                dataset,
                if *partition == "iid" { "iid" } else { "noniid" }
            );
            let mut base = ExperimentConfig::load(&format!("configs/{cfg_name}.json"))?;
            base.partition = if *partition == "iid" {
                Partition::Iid
            } else {
                Partition::Dirichlet(0.5)
            };
            if rounds_override > 0 {
                base.rounds = rounds_override;
            }
            let variants: Vec<ExperimentConfig> =
                codecs.iter().map(|c| with_codec(&base, c)).collect();
            let runs = run_suite(variants)?;
            print_convergence_table(
                &format!("Fig. 2 panel: {dataset} / {partition}"),
                &runs,
            );
        }
    }
    Ok(())
}
