//! Fig. 2 reproduction: test accuracy vs communication rounds for SL-FAC
//! against PQ-SL, TK-SL and FC-SL, on both datasets, IID and non-IID.
//!
//! The grid itself is declarative — `configs/sweeps/fig2_convergence.json`
//! (dataset × partition × codec, with the byte-parity calibration on each
//! baseline codec's axis entry) — and runs through the sweep
//! orchestrator, so it checkpoints per run and resumes mid-grid:
//!
//! ```text
//! cargo run --release --example fig2_convergence -- [--workers N]
//! # equivalently: slfac sweep run --spec configs/sweeps/fig2_convergence.json
//! ```
//!
//! Writes one CSV per run plus journal + `slfac-sweep/1` report under
//! `results/fig2/`, and prints the paper-style convergence grids. Expect
//! ~45 s per run at the default 15 rounds on a laptop-class CPU.

use slfac::cli::Command;
use slfac::experiments::print_sweep_tables;
use slfac::sweep::{run_sweep, SweepOptions, SweepSpec};

fn main() -> anyhow::Result<()> {
    slfac::logging::init_from_env();
    let cmd = Command::new("fig2_convergence", "paper Fig. 2 reproduction")
        .opt(
            "spec",
            "PATH",
            "sweep spec",
            Some("configs/sweeps/fig2_convergence.json"),
        )
        .opt("workers", "N", "concurrent runs (0 = auto)", None)
        .opt("out-dir", "DIR", "results root", Some("results"));
    let m = match cmd.parse() {
        Ok(m) => m,
        Err(slfac::cli::CliError::Help(h)) => {
            println!("{h}");
            return Ok(());
        }
        Err(slfac::cli::CliError::Bad(e)) => anyhow::bail!(e),
    };
    let spec = SweepSpec::load(m.req("spec").map_err(anyhow::Error::msg)?)?;
    let opts = SweepOptions {
        workers: m.get_parsed("workers").map_err(anyhow::Error::msg)?,
        out_dir: m.req("out-dir").map_err(anyhow::Error::msg)?.to_string(),
        ..Default::default()
    };
    let outcome = run_sweep(&spec, &opts)?;
    print_sweep_tables("Fig. 2 panel", &outcome.results);
    println!(
        "\n{} of {} runs journaled; report -> {}",
        outcome.completed, outcome.grid, outcome.report_path
    );
    Ok(())
}
