//! Codec playground: dissect what SL-FAC does to one batch of smashed data.
//!
//! Prints, per channel: the AFD split point k*, the FQC bit allocation, the
//! spectral energy distribution, and the wire-byte breakdown — the
//! inspectability story behind Algorithm 1.
//!
//! ```text
//! cargo run --release --example codec_playground -- [--theta F] [--shape BxCxMxN]
//! ```

use slfac::cli::Command;
use slfac::codec::{self, ActivationCodec, SlFacCodec, SlFacConfig};
use slfac::dct::Dct2d;
use slfac::freq::{afd_channel, zigzag};
use slfac::quant::{allocate_bits, AllocationConfig};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("codec_playground", "inspect AFD + FQC on one tensor")
        .opt("theta", "F", "energy threshold", Some("0.9"))
        .opt("shape", "BxCxMxN", "tensor shape", Some("1x8x14x14"));
    let m = match cmd.parse() {
        Ok(m) => m,
        Err(slfac::cli::CliError::Help(h)) => {
            println!("{h}");
            return Ok(());
        }
        Err(slfac::cli::CliError::Bad(e)) => anyhow::bail!(e),
    };
    let theta: f64 = m.get_parsed("theta").map_err(anyhow::Error::msg)?.unwrap();
    let shape: Vec<usize> = m
        .req("shape")
        .map_err(anyhow::Error::msg)?
        .split('x')
        .map(|s| s.parse().unwrap())
        .collect();

    let x = codec::smooth_activations(&shape, 7);
    let coeffs = Dct2d::forward_tensor(&x);
    let (b, c, mm, nn) = coeffs.as_bchw();
    let zz = zigzag(mm, nn);
    let alloc = AllocationConfig::default();

    println!("AFD + FQC dissection (theta = {theta}, plane {mm}x{nn}, {} coeffs)\n", mm * nn);
    println!(
        "{:>4} {:>6} {:>8} {:>7} {:>7} {:>10} {:>10} {:>9}",
        "ch", "k*", "k*/MN", "b_low", "b_high", "E_low", "E_high", "bits/val"
    );
    for bi in 0..b.min(1) {
        for ci in 0..c {
            let split = afd_channel(&zz, coeffs.channel(bi, ci), theta);
            let (bl, bh) =
                allocate_bits(&alloc, split.mean_energy_low, split.mean_energy_high);
            let total = mm * nn;
            let bits = split.k * bl as usize + (total - split.k) * bh as usize;
            println!(
                "{:>4} {:>6} {:>7.1}% {:>7} {:>7} {:>10.3} {:>10.5} {:>9.2}",
                ci,
                split.k,
                100.0 * split.k as f64 / total as f64,
                bl,
                bh,
                split.mean_energy_low,
                split.mean_energy_high,
                bits as f64 / total as f64
            );
        }
    }

    // wire breakdown
    let slfac = SlFacCodec::new(SlFacConfig {
        theta,
        ..Default::default()
    });
    let payload = slfac.compress(&coeffs)?;
    let raw = x.numel() * 4;
    let headers = b * c * 12; // k* + widths + F_l range (F_h range varies)
    println!(
        "\nwire: {} B total = 28 B payload header + >= {} B channel headers + packed bits",
        payload.wire_bytes(),
        headers
    );
    println!(
        "raw fp32 {} B -> {:.1}x compression; reconstruction rel L2 err {:.4}",
        raw,
        payload.compression_ratio(),
        Dct2d::inverse_tensor(&slfac.decompress(&payload)?).rel_l2_error(&x)
    );

    // theta sweep on the same tensor (Fig. 3's mechanism)
    println!("\ntheta sweep (same tensor):");
    println!("{:>7} {:>12} {:>8} {:>10}", "theta", "wire B", "ratio", "rel err");
    for t in [0.5, 0.7, 0.8, 0.9, 0.95, 0.99] {
        let c = SlFacCodec::new(SlFacConfig {
            theta: t,
            ..Default::default()
        });
        let p = c.compress(&coeffs)?;
        let err = Dct2d::inverse_tensor(&c.decompress(&p)?).rel_l2_error(&x);
        println!(
            "{:>7.2} {:>12} {:>7.1}x {:>10.4}",
            t,
            p.wire_bytes(),
            p.compression_ratio(),
            err
        );
    }
    Ok(())
}
