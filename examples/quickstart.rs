//! Quickstart: the SL-FAC public API in three bites.
//!
//! 1. Compress a batch of activation-like data with the paper's codec and
//!    inspect the wire cost (no artifacts needed).
//! 2. Compare against a baseline at matched settings.
//! 3. Run a tiny end-to-end split-learning experiment through the PJRT
//!    runtime (requires `make artifacts`).
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use slfac::codec::{self, ActivationCodec, CodecParams, SlFacCodec, SlFacConfig};
use slfac::config::ExperimentConfig;
use slfac::coordinator::Trainer;
use slfac::dct::Dct2d;
use slfac::runtime::ExecutorHandle;

fn main() -> anyhow::Result<()> {
    slfac::logging::init_from_env();

    // ---- 1. the codec, standalone -------------------------------------
    let activations = codec::smooth_activations(&[8, 16, 14, 14], 42);
    let coeffs = Dct2d::forward_tensor(&activations); // AFD step 1 (Eq. 1)
    let slfac = SlFacCodec::new(SlFacConfig::default()); // θ=0.9, bits ∈ [2,8]
    let payload = slfac.compress(&coeffs)?;
    let restored = Dct2d::inverse_tensor(&slfac.decompress(&payload)?);
    println!(
        "SL-FAC: {} B on the wire ({:.1}x smaller than fp32), rel L2 err {:.4}",
        payload.wire_bytes(),
        payload.compression_ratio(),
        restored.rel_l2_error(&activations)
    );

    // ---- 2. against baselines -----------------------------------------
    let params = CodecParams::default();
    for name in ["pq-sl", "tk-sl", "fc-sl"] {
        let c = codec::by_name(name, &params)?;
        let (back, p) = codec::roundtrip_spatial(c.as_ref(), &activations)?;
        println!(
            "{name:>6}: {} B ({:.1}x), rel L2 err {:.4}",
            p.wire_bytes(),
            p.compression_ratio(),
            back.rel_l2_error(&activations)
        );
    }

    // ---- 3. tiny end-to-end run ---------------------------------------
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n(artifacts missing — run `make artifacts` for the e2e part)");
        return Ok(());
    }
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        rounds: 3,
        devices: 3,
        train_samples: 1000,
        test_samples: 160,
        batches_per_round: 5,
        ..Default::default()
    };
    let exec = ExecutorHandle::spawn(&cfg.artifacts_dir, &["mnist".into()])?;
    let mut trainer = Trainer::new(cfg, exec)?;
    let outcome = trainer.run()?;
    println!("\n{}", outcome.history.summary());
    Ok(())
}
