//! End-to-end validation driver (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Trains the split ResNet on the MNIST-like workload for a full
//! communication budget through ALL layers of the stack — synthetic data →
//! Rust coordinator → AFD+FQC codec → simulated links → PJRT-compiled HLO
//! (containing the L1 Pallas DCT kernel) → SplitFed aggregation — and logs
//! the loss/accuracy curve plus executor and link statistics.
//!
//! ```text
//! cargo run --release --example e2e_train -- [--rounds N] [--codec NAME]
//! ```

use slfac::cli::Command;
use slfac::config::ExperimentConfig;
use slfac::coordinator::Trainer;
use slfac::runtime::ExecutorHandle;

fn main() -> anyhow::Result<()> {
    slfac::logging::init_from_env();
    let cmd = Command::new("e2e_train", "full end-to-end training driver")
        .opt("rounds", "N", "communication rounds", Some("15"))
        .opt("codec", "NAME", "codec", Some("slfac"))
        .opt("config", "PATH", "base config", Some("configs/mnist_iid.json"));
    let m = match cmd.parse() {
        Ok(m) => m,
        Err(slfac::cli::CliError::Help(h)) => {
            println!("{h}");
            return Ok(());
        }
        Err(slfac::cli::CliError::Bad(e)) => anyhow::bail!(e),
    };

    let mut cfg = ExperimentConfig::load(m.req("config").map_err(anyhow::Error::msg)?)?;
    cfg.name = "e2e".into();
    cfg.rounds = m
        .get_parsed::<usize>("rounds")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(15);
    cfg.codec = m.req("codec").map_err(anyhow::Error::msg)?.to_string();

    println!(
        "e2e: dataset {}, {} devices, {} rounds x {} batches, codec {}",
        cfg.dataset.name(),
        cfg.devices,
        cfg.rounds,
        cfg.batches_per_round,
        cfg.codec
    );
    let exec = ExecutorHandle::spawn(&cfg.artifacts_dir, &[cfg.dataset.name().to_string()])?;
    let mut trainer = Trainer::new(cfg, exec)?;
    let outcome = trainer.run()?;

    println!("\nloss curve (round, train loss, test acc):");
    for r in &outcome.history.rounds {
        println!(
            "  {:>3}  {:>8.4}  {:>6.2}%   [{:>8} B up, {:>8} B down]",
            r.round,
            r.train_loss,
            r.test_acc * 100.0,
            r.uplink_bytes,
            r.downlink_bytes
        );
    }
    println!("\n{}", outcome.history.summary());
    println!("\nexecutor profile:");
    for (key, (n, t)) in &outcome.exec_stats.per_artifact {
        println!(
            "  {key:<22} {n:>5} execs  {:>9.3}s total  {:>8.2}ms mean",
            t.as_secs_f64(),
            t.as_secs_f64() * 1e3 / (*n as f64)
        );
    }
    println!("\nper-device links (id, up MB, down MB, busy s):");
    for (id, up, down, busy) in trainer.link_stats() {
        println!(
            "  dev{id}: {:>8.2} {:>8.2} {:>8.3}",
            up as f64 / 1e6,
            down as f64 / 1e6,
            busy
        );
    }
    outcome.history.write_csv("results/e2e_train.csv")?;
    println!("\nmetrics -> results/e2e_train.csv");
    Ok(())
}
