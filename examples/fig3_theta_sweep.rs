//! Fig. 3 reproduction: impact of the energy threshold θ on model
//! performance (MNIST, IID and non-IID).
//!
//! The grid is `configs/sweeps/fig3_theta.json` (partition × θ on the
//! SL-FAC codec), run through the sweep orchestrator:
//!
//! ```text
//! cargo run --release --example fig3_theta_sweep -- [--workers N]
//! # equivalently: slfac sweep run --spec configs/sweeps/fig3_theta.json
//! ```

use slfac::cli::Command;
use slfac::experiments::print_sweep_tables;
use slfac::sweep::{run_sweep, SweepOptions, SweepSpec};

fn main() -> anyhow::Result<()> {
    slfac::logging::init_from_env();
    let cmd = Command::new("fig3_theta_sweep", "paper Fig. 3 reproduction")
        .opt(
            "spec",
            "PATH",
            "sweep spec",
            Some("configs/sweeps/fig3_theta.json"),
        )
        .opt("workers", "N", "concurrent runs (0 = auto)", None)
        .opt("out-dir", "DIR", "results root", Some("results"));
    let m = match cmd.parse() {
        Ok(m) => m,
        Err(slfac::cli::CliError::Help(h)) => {
            println!("{h}");
            return Ok(());
        }
        Err(slfac::cli::CliError::Bad(e)) => anyhow::bail!(e),
    };
    let spec = SweepSpec::load(m.req("spec").map_err(anyhow::Error::msg)?)?;
    let opts = SweepOptions {
        workers: m.get_parsed("workers").map_err(anyhow::Error::msg)?,
        out_dir: m.req("out-dir").map_err(anyhow::Error::msg)?.to_string(),
        ..Default::default()
    };
    let outcome = run_sweep(&spec, &opts)?;
    print_sweep_tables("Fig. 3 panel", &outcome.results);
    println!(
        "\n{} of {} runs journaled; report -> {}",
        outcome.completed, outcome.grid, outcome.report_path
    );
    Ok(())
}
