//! Fig. 3 reproduction: impact of the energy threshold θ on model
//! performance (MNIST, IID and non-IID).
//!
//! ```text
//! cargo run --release --example fig3_theta_sweep -- \
//!     [--thetas 0.5,0.7,0.8,0.9,0.95] [--rounds N] [--partitions iid,non-iid]
//! ```

use slfac::cli::Command;
use slfac::config::{ExperimentConfig, Partition};
use slfac::experiments::{print_convergence_table, run_suite, with_theta};

fn main() -> anyhow::Result<()> {
    slfac::logging::init_from_env();
    let cmd = Command::new("fig3_theta_sweep", "paper Fig. 3 reproduction")
        .opt("thetas", "LIST", "θ values", Some("0.5,0.7,0.8,0.9,0.95"))
        .opt("partitions", "LIST", "iid,non-iid", Some("iid,non-iid"))
        .opt("rounds", "N", "override rounds (0 = config default)", Some("0"));
    let m = match cmd.parse() {
        Ok(m) => m,
        Err(slfac::cli::CliError::Help(h)) => {
            println!("{h}");
            return Ok(());
        }
        Err(slfac::cli::CliError::Bad(e)) => anyhow::bail!(e),
    };
    let thetas: Vec<f64> = m
        .req("thetas")
        .map_err(anyhow::Error::msg)?
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let partitions: Vec<&str> = m.req("partitions").map_err(anyhow::Error::msg)?.split(',').collect();
    let rounds_override: usize = m.get_parsed("rounds").map_err(anyhow::Error::msg)?.unwrap_or(0);

    for partition in &partitions {
        let cfg_name = if *partition == "iid" { "mnist_iid" } else { "mnist_noniid" };
        let mut base = ExperimentConfig::load(&format!("configs/{cfg_name}.json"))?;
        base.partition = if *partition == "iid" {
            Partition::Iid
        } else {
            Partition::Dirichlet(0.5)
        };
        base.codec = "slfac".into();
        if rounds_override > 0 {
            base.rounds = rounds_override;
        }
        let variants: Vec<ExperimentConfig> =
            thetas.iter().map(|&t| with_theta(&base, t)).collect();
        let mut runs = run_suite(variants)?;
        // label columns by theta instead of codec
        for (run, &t) in runs.iter_mut().zip(&thetas) {
            run.cfg.codec = format!("θ={t}");
        }
        print_convergence_table(&format!("Fig. 3 panel: MNIST / {partition}"), &runs);
    }
    Ok(())
}
